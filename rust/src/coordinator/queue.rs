//! Bounded MPMC queue with blocking push — the backpressure primitive.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    /// high-water mark, for metrics
    max_depth: usize,
}

/// A bounded queue: `push` blocks while full (backpressure), `pop`
/// blocks while empty, `close` wakes everyone. Multi-producer,
/// multi-consumer.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

/// Push outcome when the queue is closed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Closed;

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "capacity must be positive");
        Self {
            inner: Mutex::new(Inner { items: VecDeque::new(),
                                      closed: false, max_depth: 0 }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push; returns Err(Closed) if the queue was closed.
    pub fn push(&self, item: T) -> Result<(), Closed> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if g.closed {
                return Err(Closed);
            }
            if g.items.len() < self.capacity {
                g.items.push_back(item);
                let depth = g.items.len();
                g.max_depth = g.max_depth.max(depth);
                drop(g);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).expect("queue poisoned");
        }
    }

    /// Blocking pop; returns None when the queue is closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).expect("queue poisoned");
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        let item = g.items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close: producers get Err, consumers drain then get None.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue poisoned");
        g.closed = true;
        drop(g);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn max_depth(&self) -> usize {
        self.inner.lock().expect("queue poisoned").max_depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn backpressure_blocks_producer() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            q2.push(3).unwrap(); // must block until a pop
            std::time::Instant::now()
        });
        std::thread::sleep(Duration::from_millis(50));
        let before_pop = std::time::Instant::now();
        assert_eq!(q.pop(), Some(1));
        let unblocked_at = producer.join().unwrap();
        assert!(unblocked_at >= before_pop,
                "producer must only proceed after the pop");
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.push(3), Err(Closed));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q = Arc::new(BoundedQueue::<i32>::new(2));
        let q2 = Arc::clone(&q);
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(30));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn mpmc_stress_no_loss() {
        let q = Arc::new(BoundedQueue::new(4));
        let mut handles = Vec::new();
        for p in 0..4u64 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..250u64 {
                    q.push(p * 1000 + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), 1000);
        all.dedup();
        assert_eq!(all.len(), 1000, "duplicates observed");
        assert!(q.max_depth() <= 4);
    }

    #[test]
    fn try_pop_nonblocking() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.try_pop(), None);
        q.push(9).unwrap();
        assert_eq!(q.try_pop(), Some(9));
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn zero_capacity_rejected() {
        BoundedQueue::<i32>::new(0);
    }
}
