//! The campaign scheduler — since the serve-layer unification a thin
//! adapter over [`crate::serve`]: tuning points are submitted as
//! [`WorkItem::point`]s to the unified front queue, routed by the
//! dispatcher to one shard per architecture, and evaluated there. The
//! public API (`new`, `run_batch`, `cancel`, `metrics`, `park`) is
//! unchanged; the private worker pool, queue and drain logic that used
//! to live here are gone — there is exactly one worker-loop
//! implementation in the repo now (`serve::shard_loop`).
//!
//! The result cache is deliberately disabled for campaigns: `run_batch`
//! is a measurement path and must evaluate every submitted point.

use std::sync::Arc;

use crate::serve::{Output, Serve, ServeConfig, ServeError, WorkItem};
use crate::sim::TuningPoint;
use crate::tuner::SweepRecord;

use super::jobs::JobResult;
use super::metrics::Metrics;

pub use crate::serve::MachinePark;

/// The campaign scheduler (compatibility shim over the serve layer).
pub struct Scheduler {
    serve: Serve,
    /// Legacy counter view; fed by this shim so existing callers and
    /// tests keep their contract. New code should read
    /// `serve::ServeMetrics` instead.
    pub metrics: Arc<Metrics>,
}

impl Scheduler {
    /// Spawn a scheduler: `workers` evaluation threads per architecture
    /// shard over an admission queue of `queue_cap` slots.
    pub fn new(workers: usize, queue_cap: usize) -> Self {
        let cfg = ServeConfig {
            front_cap: queue_cap.max(1),
            shard_cap: queue_cap.max(1),
            max_batch: 8,
            cache_cap: 0, // measurement path: never serve stale results
            sim_threads: workers.max(1),
            native: None,
            // campaigns never shed: every submitted point must evaluate
            ..ServeConfig::default()
        };
        let serve = Serve::start(cfg)
            .expect("sim-only serve layer cannot fail to start");
        Self { serve, metrics: Arc::new(Metrics::new()) }
    }

    /// Access the machine park (e.g. to pre-warm trace caches).
    pub fn park(&self) -> &MachinePark {
        self.serve.park().as_ref()
    }

    /// Request cancellation: queued jobs are drained without evaluation.
    pub fn cancel(&self) {
        self.serve.cancel();
    }

    pub fn cancelled(&self) -> bool {
        self.serve.cancelled()
    }

    /// Evaluate a batch of points; blocks until all results are in and
    /// returns them ordered by submission index. Cancelled jobs are
    /// omitted (and counted as failed in the legacy metrics, exactly as
    /// the pre-serve scheduler did).
    pub fn run_batch(&self, points: Vec<TuningPoint>) -> Vec<JobResult> {
        let mut pending = Vec::with_capacity(points.len());
        for (i, point) in points.into_iter().enumerate() {
            self.metrics.job_submitted();
            pending.push((i as u64, self.serve
                .submit(WorkItem::point(point))));
        }
        // Legacy queue-depth metric: the front queue's own high-water
        // (+1 for the in-flight item, matching the old per-submit
        // `len() + 1` observation) — one read instead of one per job.
        self.metrics.observe_queue_depth(
            self.serve.front_depth_high_water() + 1);
        let mut out: Vec<JobResult> = Vec::with_capacity(pending.len());
        for (id, rx) in pending {
            let reply = rx.recv().unwrap_or(Err(ServeError::Closed));
            match reply {
                Ok(r) => match r.output {
                    Output::Sim { record, wall } => {
                        self.metrics.job_completed(wall);
                        out.push(JobResult { id, record, worker: r.worker,
                                             wall });
                    }
                    _ => self.metrics.job_failed(),
                },
                Err(_) => self.metrics.job_failed(),
            }
        }
        out.sort_by_key(|r| r.id);
        out
    }

    /// One-off evaluation through the same path as `run_batch`.
    pub fn run_one(&self, point: TuningPoint) -> Option<SweepRecord> {
        self.run_batch(vec![point]).pop().map(|r| r.record)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;
    use crate::sim::Machine;
    use crate::tuner::TuningSpace;

    fn points(n: u64) -> Vec<TuningPoint> {
        TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                           Precision::F64, n)
            .points()
    }

    #[test]
    fn batch_results_ordered_and_complete() {
        let sched = Scheduler::new(4, 4);
        let pts = points(2048);
        let n = pts.len();
        let results = sched.run_batch(pts.clone());
        assert_eq!(results.len(), n);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.record.point, pts[i]);
            assert!(r.record.gflops > 0.0);
        }
        assert_eq!(sched.metrics.completed(), n as u64);
        assert_eq!(sched.metrics.failed(), 0);
    }

    #[test]
    fn small_queue_forces_backpressure_but_loses_nothing() {
        let sched = Scheduler::new(2, 1);
        let pts = points(1024);
        let results = sched.run_batch(pts.clone());
        assert_eq!(results.len(), pts.len());
        assert!(sched.metrics.max_queue_depth() <= 2);
    }

    #[test]
    fn mixed_arch_batch() {
        let sched = Scheduler::new(4, 8);
        let mut pts = points(1024);
        pts.push(TuningPoint::gpu(ArchId::P100Nvlink, Precision::F32,
                                  1024, 4));
        pts.push(TuningPoint::gpu(ArchId::K80, Precision::F64, 1024, 2));
        let results = sched.run_batch(pts.clone());
        assert_eq!(results.len(), pts.len());
    }

    #[test]
    fn cancellation_stops_evaluation() {
        let sched = Scheduler::new(1, 2);
        sched.cancel();
        let results = sched.run_batch(points(1024));
        assert!(results.is_empty());
        assert!(sched.metrics.failed() > 0);
    }

    #[test]
    fn scheduler_agrees_with_direct_predict() {
        let sched = Scheduler::new(3, 4);
        let pts = points(2048);
        let results = sched.run_batch(pts.clone());
        let m = Machine::for_arch(ArchId::Knl);
        for r in &results {
            let direct = m.predict(&r.record.point);
            assert!((direct.gflops - r.record.gflops).abs() < 1e-9);
        }
    }

    #[test]
    fn run_one_matches_batch() {
        let sched = Scheduler::new(2, 4);
        let p = points(1024)[0];
        let one = sched.run_one(p).unwrap();
        assert_eq!(one.point, p);
        assert!(one.gflops > 0.0);
    }
}
