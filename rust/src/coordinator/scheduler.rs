//! The scheduler: a worker pool draining a bounded job queue, with
//! per-architecture machine-model instances, cancellation and metrics.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::arch::ArchId;
use crate::sim::{Machine, TuningPoint};
use crate::tuner::SweepRecord;

use super::jobs::{JobResult, JobSpec};
use super::metrics::Metrics;
use super::queue::BoundedQueue;

/// Shared machine-model registry: one memoised instance per arch.
#[derive(Default)]
pub struct MachinePark {
    machines: Mutex<HashMap<ArchId, Arc<Machine>>>,
}

impl MachinePark {
    pub fn get(&self, arch: ArchId) -> Arc<Machine> {
        let mut g = self.machines.lock().expect("park poisoned");
        Arc::clone(g.entry(arch)
                   .or_insert_with(|| Arc::new(Machine::for_arch(arch))))
    }
}

/// The campaign scheduler.
pub struct Scheduler {
    queue: Arc<BoundedQueue<(JobSpec, Sender<JobResult>)>>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    cancel: Arc<AtomicBool>,
    park: Arc<MachinePark>,
}

impl Scheduler {
    /// Spawn `workers` workers over a queue of `queue_cap` slots.
    pub fn new(workers: usize, queue_cap: usize) -> Self {
        let queue: Arc<BoundedQueue<(JobSpec, Sender<JobResult>)>> =
            Arc::new(BoundedQueue::new(queue_cap.max(1)));
        let metrics = Arc::new(Metrics::new());
        let cancel = Arc::new(AtomicBool::new(false));
        let park = Arc::new(MachinePark::default());
        let handles = (0..workers.max(1))
            .map(|widx| {
                let queue = Arc::clone(&queue);
                let metrics = Arc::clone(&metrics);
                let cancel = Arc::clone(&cancel);
                let park = Arc::clone(&park);
                std::thread::Builder::new()
                    .name(format!("alpaka-sched-{widx}"))
                    .spawn(move || {
                        worker_loop(widx, &queue, &metrics, &cancel, &park)
                    })
                    .expect("spawn scheduler worker")
            })
            .collect();
        Self { queue, workers: handles, metrics, cancel, park }
    }

    /// Access the machine park (e.g. to pre-warm trace caches).
    pub fn park(&self) -> &MachinePark {
        &self.park
    }

    /// Request cancellation: queued jobs are drained without evaluation.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Evaluate a batch of points; blocks until all results are in and
    /// returns them ordered by submission index. Cancelled jobs are
    /// omitted.
    pub fn run_batch(&self, points: Vec<TuningPoint>) -> Vec<JobResult> {
        let (tx, rx) = channel::<JobResult>();
        let n = points.len();
        for (i, point) in points.into_iter().enumerate() {
            let spec = JobSpec { id: i as u64, point };
            self.metrics.job_submitted();
            self.metrics.observe_queue_depth(self.queue.len() + 1);
            if self.queue.push((spec, tx.clone())).is_err() {
                break; // shut down
            }
        }
        drop(tx);
        let mut out: Vec<JobResult> = rx.into_iter().collect();
        out.sort_by_key(|r| r.id);
        debug_assert!(out.len() <= n);
        out
    }
}

fn worker_loop(widx: usize,
               queue: &BoundedQueue<(JobSpec, Sender<JobResult>)>,
               metrics: &Metrics, cancel: &AtomicBool,
               park: &MachinePark) {
    while let Some((spec, tx)) = queue.pop() {
        if cancel.load(Ordering::SeqCst) {
            metrics.job_failed(); // cancelled counts as not-completed
            continue;
        }
        let t0 = Instant::now();
        let machine = park.get(spec.point.arch);
        let pred = machine.predict(&spec.point);
        let wall = t0.elapsed().as_secs_f64();
        metrics.job_completed(wall);
        let _ = tx.send(JobResult {
            id: spec.id,
            record: SweepRecord::new(spec.point, &pred),
            worker: widx,
            wall,
        });
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CompilerId;
    use crate::gemm::Precision;
    use crate::tuner::TuningSpace;

    fn points(n: u64) -> Vec<TuningPoint> {
        TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                           Precision::F64, n)
            .points()
    }

    #[test]
    fn batch_results_ordered_and_complete() {
        let sched = Scheduler::new(4, 4);
        let pts = points(2048);
        let n = pts.len();
        let results = sched.run_batch(pts.clone());
        assert_eq!(results.len(), n);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.record.point, pts[i]);
            assert!(r.record.gflops > 0.0);
        }
        assert_eq!(sched.metrics.completed(), n as u64);
        assert_eq!(sched.metrics.failed(), 0);
    }

    #[test]
    fn small_queue_forces_backpressure_but_loses_nothing() {
        let sched = Scheduler::new(2, 1);
        let pts = points(1024);
        let results = sched.run_batch(pts.clone());
        assert_eq!(results.len(), pts.len());
        assert!(sched.metrics.max_queue_depth() <= 2);
    }

    #[test]
    fn mixed_arch_batch() {
        let sched = Scheduler::new(4, 8);
        let mut pts = points(1024);
        pts.push(TuningPoint::gpu(ArchId::P100Nvlink, Precision::F32,
                                  1024, 4));
        pts.push(TuningPoint::gpu(ArchId::K80, Precision::F64, 1024, 2));
        let results = sched.run_batch(pts.clone());
        assert_eq!(results.len(), pts.len());
    }

    #[test]
    fn cancellation_stops_evaluation() {
        let sched = Scheduler::new(1, 2);
        sched.cancel();
        let results = sched.run_batch(points(1024));
        assert!(results.is_empty());
        assert!(sched.metrics.failed() > 0);
    }

    #[test]
    fn scheduler_agrees_with_direct_predict() {
        let sched = Scheduler::new(3, 4);
        let pts = points(2048);
        let results = sched.run_batch(pts.clone());
        let m = Machine::for_arch(ArchId::Knl);
        for r in &results {
            let direct = m.predict(&r.record.point);
            assert!((direct.gflops - r.record.gflops).abs() < 1e-9);
        }
    }
}
