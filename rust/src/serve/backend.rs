//! Serve-layer backends: the single execution abstraction behind every
//! shard.
//!
//! The paper's thesis — one implementation, tuned per backend — applied
//! to the serving plane: a [`Backend`] turns one [`WorkItem`] into one
//! [`Output`], and everything else (queueing, batching, caching,
//! metrics) lives once in the shard loop instead of once per subsystem.
//!
//! Two backend families exist today:
//!
//! * [`SimBackend`] — machine-model prediction for a simulated
//!   architecture (one shard per [`ArchId`]);
//! * [`NativeBackend`] — execution on the host, via PJRT when the real
//!   `xla_extension` is linked, falling back to the independent host
//!   reference GEMM when device execution is unavailable (the vendored
//!   stub build, or a PJRT runtime failure at serve time). The fallback
//!   is reported explicitly in [`Output::Native`], never silently.
//!
//! Adding a third backend family means implementing [`Backend`] and
//! giving [`WorkItem`] a routing case — no new worker loop, no new
//! queue, no new metrics (see `lib.rs` crate docs and ROADMAP).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use std::sync::Mutex;

use crate::arch::ArchId;
use crate::gemm::{metrics as gemm_metrics, verify, Precision};
use crate::runtime::artifact::Manifest;
use crate::runtime::client::{LoadedKernel, Runtime};
use crate::sim::{Machine, TuningPoint};
use crate::tuner::SweepRecord;
use crate::util::prng;

/// Shared machine-model registry: one memoised [`Machine`] per
/// architecture. Lives here because every sim shard draws from it; the
/// coordinator's `Scheduler` re-exports it for backwards compatibility.
#[derive(Default)]
pub struct MachinePark {
    machines: Mutex<HashMap<ArchId, Arc<Machine>>>,
}

impl MachinePark {
    pub fn get(&self, arch: ArchId) -> Arc<Machine> {
        let mut g = self.machines.lock().expect("park poisoned");
        Arc::clone(g.entry(arch)
                   .or_insert_with(|| Arc::new(Machine::for_arch(arch))))
    }
}

/// One unit of serveable work.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkItem {
    /// Evaluate a tuning point on its architecture's machine model.
    Point(TuningPoint),
    /// Execute a lowered artifact on the native backend.
    Artifact(String),
}

impl WorkItem {
    /// Which shard serves this item.
    pub fn shard_key(&self) -> ShardKey {
        match self {
            WorkItem::Point(p) => ShardKey::Sim(p.arch),
            WorkItem::Artifact(_) => ShardKey::Native,
        }
    }

    /// Canonical key for batching and the result cache. Two items with
    /// equal keys are interchangeable executions.
    pub fn cache_key(&self) -> String {
        match self {
            WorkItem::Point(p) => format!("point:{p:?}"),
            WorkItem::Artifact(id) => format!("artifact:{id}"),
        }
    }
}

/// Shard identity: one per simulated architecture plus the single-owner
/// native shard (the PJRT client is Rc-based — exactly one owner thread).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardKey {
    Sim(ArchId),
    Native,
}

impl ShardKey {
    pub fn label(&self) -> String {
        match self {
            ShardKey::Sim(a) => format!("sim:{}", a.slug()),
            ShardKey::Native => "native".to_string(),
        }
    }
}

/// Which engine actually served a native request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeEngine {
    Pjrt,
    HostGemm,
}

/// A completed execution.
#[derive(Debug, Clone)]
pub enum Output {
    /// Machine-model prediction (simulated shards).
    Sim {
        record: SweepRecord,
        /// Model-evaluation wall time in seconds.
        wall: f64,
    },
    /// Native execution (PJRT or host reference GEMM).
    Native {
        artifact_id: String,
        seconds: f64,
        gflops: Option<f64>,
        engine: NativeEngine,
    },
}

/// The execution abstraction every shard drives. Implementations are
/// created *inside* the shard thread (the PJRT client is not `Send`),
/// hence the `Send` factory type below rather than a `Send` bound here.
pub trait Backend {
    fn label(&self) -> String;
    fn run(&mut self, item: &WorkItem) -> Result<Output, String>;
}

/// Constructor executed on the shard thread.
pub type BackendFactory =
    Box<dyn FnOnce() -> Result<Box<dyn Backend>, String> + Send>;

// ---------------------------------------------------------------- sim --

/// Machine-model backend for one simulated architecture.
pub struct SimBackend {
    arch: ArchId,
    machine: Arc<Machine>,
}

impl SimBackend {
    pub fn new(arch: ArchId, park: &MachinePark) -> Self {
        Self { arch, machine: park.get(arch) }
    }
}

impl Backend for SimBackend {
    fn label(&self) -> String {
        ShardKey::Sim(self.arch).label()
    }

    fn run(&mut self, item: &WorkItem) -> Result<Output, String> {
        match item {
            WorkItem::Point(p) => {
                if p.arch != self.arch {
                    return Err(format!(
                        "routing bug: {} point on {} shard",
                        p.arch.label(), self.arch.label()));
                }
                let t0 = Instant::now();
                let pred = self.machine.predict(p);
                Ok(Output::Sim {
                    record: SweepRecord::new(*p, &pred),
                    wall: t0.elapsed().as_secs_f64(),
                })
            }
            WorkItem::Artifact(id) => Err(format!(
                "sim shard {} cannot execute artifact {id}",
                self.arch.label())),
        }
    }
}

// ------------------------------------------------------------- native --

/// What the native backend knows about one artifact, independent of the
/// engine that ends up executing it.
#[derive(Debug, Clone)]
pub struct NativeSpec {
    pub id: String,
    pub n: u64,
    pub precision: Precision,
    pub flops: Option<u128>,
    /// Input seeds (a, b, c). `c` is unused for 2-input dot baselines.
    pub seeds: Vec<u64>,
    /// GEMM coefficients (from the manifest; 1.0/1.0 for synthetics).
    pub alpha: f64,
    pub beta: f64,
    /// Whether the host reference GEMM can legally reproduce this
    /// artifact (square shapes with known seeds).
    pub host_capable: bool,
}

/// Largest N the host fallback will multiply (O(N^3) on one thread).
const HOST_GEMM_MAX_N: u64 = 1024;

enum HostInputs {
    F32 { a: Vec<f32>, b: Vec<f32>, c: Vec<f32> },
    F64 { a: Vec<f64>, b: Vec<f64>, c: Vec<f64> },
}

struct PjrtEngine {
    runtime: Runtime,
    manifest: Manifest,
    kernels: HashMap<String, (LoadedKernel, Vec<xla::Literal>)>,
}

enum PjrtFailure {
    /// This artifact cannot be served over PJRT; the engine is fine.
    Artifact(String),
    /// Device execution is unavailable; fall back for all requests.
    Engine(String),
}

impl PjrtEngine {
    fn run(&mut self, id: &str) -> Result<f64, PjrtFailure> {
        if !self.kernels.contains_key(id) {
            let meta = self.manifest.by_id(id).ok_or_else(|| {
                PjrtFailure::Artifact(format!("unknown artifact {id}"))
            })?;
            let kernel =
                self.runtime.load(&self.manifest, meta).map_err(|e| {
                    PjrtFailure::Artifact(format!("load {id}: {e:#}"))
                })?;
            let inputs = kernel.make_inputs().map_err(|e| {
                PjrtFailure::Artifact(format!("inputs {id}: {e:#}"))
            })?;
            self.kernels.insert(id.to_string(), (kernel, inputs));
        }
        let (kernel, inputs) = self.kernels.get(id).expect("just inserted");
        let t0 = Instant::now();
        kernel
            .execute_only(inputs)
            .map_err(|e| PjrtFailure::Engine(format!("{e:#}")))?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

/// The native shard's backend: PJRT first, host reference GEMM fallback.
pub struct NativeBackend {
    catalog: HashMap<String, NativeSpec>,
    pjrt: Option<PjrtEngine>,
    /// Set after the first engine-level PJRT failure; logged once.
    pjrt_dead: bool,
    host_inputs: HashMap<String, HostInputs>,
}

impl NativeBackend {
    /// Backend over a loaded artifacts manifest. PJRT client creation is
    /// attempted eagerly; failure leaves only the host fallback (and is
    /// reported per-request for artifacts the fallback cannot serve).
    pub fn from_manifest(manifest: Manifest) -> Self {
        let catalog = manifest
            .artifacts
            .iter()
            .map(|meta| {
                let n = meta.n.unwrap_or(0);
                let square_inputs = meta.inputs.len() >= 2
                    && meta.inputs.iter().all(|i| {
                        i.shape.len() == 2
                            && i.shape[0] as u64 == n
                            && i.shape[1] as u64 == n
                    });
                let host_capable = (meta.kind == "gemm"
                                    || meta.kind == "dot")
                    && n > 0
                    && n <= HOST_GEMM_MAX_N
                    && square_inputs;
                let spec = NativeSpec {
                    id: meta.id.clone(),
                    n,
                    precision: meta.precision,
                    flops: meta.flops,
                    seeds: meta.inputs.iter().map(|i| i.seed).collect(),
                    alpha: meta.alpha,
                    beta: meta.beta,
                    host_capable,
                };
                (meta.id.clone(), spec)
            })
            .collect();
        let pjrt = match Runtime::new() {
            Ok(runtime) => Some(PjrtEngine {
                runtime,
                manifest,
                kernels: HashMap::new(),
            }),
            Err(e) => {
                eprintln!("[serve] PJRT unavailable ({e:#}); native \
                           shard uses the host reference GEMM");
                None
            }
        };
        Self { catalog, pjrt, pjrt_dead: false,
               host_inputs: HashMap::new() }
    }

    /// Manifest-less backend over synthetic artifact ids (load testing
    /// without `make artifacts`). Ids must parse — see
    /// [`parse_artifact_id`].
    pub fn synthetic(ids: &[String]) -> Result<Self, String> {
        let mut catalog = HashMap::new();
        for id in ids {
            let (n, precision) = parse_artifact_id(id)
                .ok_or_else(|| format!(
                    "cannot synthesize artifact id {id:?} (expected \
                     gemm_n<N>_t<T>_e<E>_<f32|f64> or dot_n<N>_<f32|f64> \
                     with default alpha/beta)"))?;
            if n > HOST_GEMM_MAX_N {
                return Err(format!(
                    "synthetic artifact {id}: N={n} exceeds host \
                     fallback limit {HOST_GEMM_MAX_N}"));
            }
            // Real dot artifacts have 2 inputs (C is implicitly zero);
            // gemms have 3. Mirror that so the synthetic catalog
            // computes the same thing the manifest-backed one would.
            let n_inputs = if id.starts_with("dot_") { 2 } else { 3 };
            let spec = NativeSpec {
                id: id.clone(),
                n,
                precision,
                flops: Some(gemm_metrics::flops(n)),
                seeds: (0..n_inputs)
                    .map(|k| prng::seed_for(id, k))
                    .collect(),
                alpha: 1.0,
                beta: 1.0,
                host_capable: true,
            };
            catalog.insert(id.clone(), spec);
        }
        Ok(Self { catalog, pjrt: None, pjrt_dead: false,
                  host_inputs: HashMap::new() })
    }

    pub fn artifact_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self.catalog.keys().cloned().collect();
        ids.sort();
        ids
    }

    fn host_run(&mut self, spec: &NativeSpec) -> Result<f64, String> {
        if !spec.host_capable {
            return Err(format!(
                "artifact {} needs the PJRT runtime (host fallback only \
                 reproduces square gemm/dot with known seeds)",
                spec.id));
        }
        let n = spec.n as usize;
        if !self.host_inputs.contains_key(&spec.id) {
            let seed = |k: usize| {
                spec.seeds.get(k).copied()
                    .unwrap_or_else(|| prng::seed_for(&spec.id, k as u64))
            };
            let inputs = match spec.precision {
                Precision::F32 => HostInputs::F32 {
                    a: prng::matrix_f32(seed(0), n, n),
                    b: prng::matrix_f32(seed(1), n, n),
                    c: if spec.seeds.len() >= 3 {
                        prng::matrix_f32(seed(2), n, n)
                    } else {
                        vec![0.0; n * n]
                    },
                },
                Precision::F64 => HostInputs::F64 {
                    a: prng::matrix_f64(seed(0), n, n),
                    b: prng::matrix_f64(seed(1), n, n),
                    c: if spec.seeds.len() >= 3 {
                        prng::matrix_f64(seed(2), n, n)
                    } else {
                        vec![0.0; n * n]
                    },
                },
            };
            self.host_inputs.insert(spec.id.clone(), inputs);
        }
        // 2-input dot baselines multiply into a zero C (so any beta is
        // inert); coefficients come from the manifest spec, 1/1 for
        // synthetics.
        let inputs = self.host_inputs.get(&spec.id).expect("just inserted");
        let t0 = Instant::now();
        match inputs {
            HostInputs::F32 { a, b, c } => {
                let out = verify::gemm_f32(n, a, b, c,
                                           spec.alpha as f32,
                                           spec.beta as f32);
                std::hint::black_box(&out);
            }
            HostInputs::F64 { a, b, c } => {
                let out = verify::gemm_f64(n, a, b, c, spec.alpha,
                                           spec.beta);
                std::hint::black_box(&out);
            }
        }
        Ok(t0.elapsed().as_secs_f64())
    }
}

impl Backend for NativeBackend {
    fn label(&self) -> String {
        ShardKey::Native.label()
    }

    fn run(&mut self, item: &WorkItem) -> Result<Output, String> {
        let id = match item {
            WorkItem::Artifact(id) => id,
            WorkItem::Point(p) => {
                return Err(format!(
                    "native shard cannot evaluate simulated point on {}",
                    p.arch.label()));
            }
        };
        let spec = self
            .catalog
            .get(id)
            .ok_or_else(|| format!("unknown artifact {id}"))?
            .clone();

        // PJRT first (when linked and not known-dead) …
        if !self.pjrt_dead {
            if let Some(engine) = self.pjrt.as_mut() {
                match engine.run(id) {
                    Ok(seconds) => {
                        return Ok(Output::Native {
                            artifact_id: id.clone(),
                            seconds,
                            gflops: spec.flops.map(|f| {
                                f as f64 / seconds / 1e9
                            }),
                            engine: NativeEngine::Pjrt,
                        });
                    }
                    Err(PjrtFailure::Artifact(msg)) => return Err(msg),
                    Err(PjrtFailure::Engine(msg)) => {
                        eprintln!("[serve] PJRT execution failed ({msg}); \
                                   switching native shard to the host \
                                   reference GEMM");
                        self.pjrt_dead = true;
                    }
                }
            }
        }

        // … host reference GEMM otherwise.
        let seconds = self.host_run(&spec)?;
        Ok(Output::Native {
            artifact_id: id.clone(),
            seconds,
            gflops: spec.flops.map(|f| f as f64 / seconds / 1e9),
            engine: NativeEngine::HostGemm,
        })
    }
}

/// Parse a synthetic artifact id of the forms the AOT path emits:
/// `gemm_n<N>_t<T>_e<E>_<f32|f64>` or `dot_n<N>_<f32|f64>`. Returns
/// `(n, precision)`, or `None` for anything else — including
/// alpha/beta-suffixed ids (`…_a1.5_b0.5`), which the host fallback must
/// not silently misreproduce with default coefficients.
pub fn parse_artifact_id(id: &str) -> Option<(u64, Precision)> {
    let toks: Vec<&str> = id.split('_').collect();
    if toks.len() < 3 || (toks[0] != "gemm" && toks[0] != "dot") {
        return None;
    }
    let n: u64 = toks[1].strip_prefix('n')?.parse().ok()?;
    let precision = Precision::parse(toks.last()?)?;
    // middle tokens must be t<digits> / e<digits> only
    for t in &toks[2..toks.len() - 1] {
        let bytes = t.as_bytes();
        if bytes.len() < 2
            || !(bytes[0] == b't' || bytes[0] == b'e')
            || !bytes[1..].iter().all(u8::is_ascii_digit)
        {
            return None;
        }
    }
    if n == 0 {
        return None;
    }
    Some((n, precision))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CompilerId;

    #[test]
    fn work_item_routing_and_keys() {
        let p = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 1024, 64, 1);
        let w = WorkItem::Point(p);
        assert_eq!(w.shard_key(), ShardKey::Sim(ArchId::Knl));
        let a = WorkItem::Artifact("dot_n128_f32".into());
        assert_eq!(a.shard_key(), ShardKey::Native);
        assert_ne!(w.cache_key(), a.cache_key());
        assert_eq!(a.cache_key(),
                   WorkItem::Artifact("dot_n128_f32".into()).cache_key());
    }

    #[test]
    fn id_parser_accepts_canonical_forms() {
        assert_eq!(parse_artifact_id("gemm_n128_t16_e1_f32"),
                   Some((128, Precision::F32)));
        assert_eq!(parse_artifact_id("gemm_n256_t32_e4_f64"),
                   Some((256, Precision::F64)));
        assert_eq!(parse_artifact_id("dot_n128_f32"),
                   Some((128, Precision::F32)));
    }

    #[test]
    fn id_parser_rejects_alpha_beta_and_junk() {
        assert_eq!(parse_artifact_id("gemm_n128_t16_e1_f32_a1.5_b0.5"),
                   None);
        assert_eq!(parse_artifact_id("mlp_b32_f32"), None);
        assert_eq!(parse_artifact_id("gemm_nX_t16_e1_f32"), None);
        assert_eq!(parse_artifact_id("gemm_n0_t16_e1_f32"), None);
        assert_eq!(parse_artifact_id(""), None);
    }

    #[test]
    fn sim_backend_predicts_and_guards_routing() {
        let park = MachinePark::default();
        let mut b = SimBackend::new(ArchId::Knl, &park);
        let p = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 1024, 64, 1);
        match b.run(&WorkItem::Point(p)).unwrap() {
            Output::Sim { record, wall } => {
                assert!(record.gflops > 0.0);
                assert!(wall >= 0.0);
            }
            other => panic!("unexpected output {other:?}"),
        }
        // wrong-arch point and artifact both refused
        let wrong = TuningPoint::gpu(ArchId::K80, Precision::F32, 256, 4);
        assert!(b.run(&WorkItem::Point(wrong)).is_err());
        assert!(b.run(&WorkItem::Artifact("dot_n128_f32".into()))
                 .is_err());
    }

    #[test]
    fn synthetic_native_backend_serves_host_gemm() {
        let ids = vec!["gemm_n64_t16_e1_f32".to_string(),
                       "dot_n64_f64".to_string()];
        let mut b = NativeBackend::synthetic(&ids).unwrap();
        assert_eq!(b.artifact_ids(), {
            let mut s = ids.clone();
            s.sort();
            s
        });
        match b.run(&WorkItem::Artifact(ids[0].clone())).unwrap() {
            Output::Native { artifact_id, seconds, gflops, engine } => {
                assert_eq!(artifact_id, ids[0]);
                assert!(seconds > 0.0);
                assert!(gflops.unwrap() > 0.0);
                assert_eq!(engine, NativeEngine::HostGemm);
            }
            other => panic!("unexpected output {other:?}"),
        }
        assert!(b.run(&WorkItem::Artifact("nope".into())).unwrap_err()
                 .contains("unknown artifact"));
    }

    #[test]
    fn synthetic_rejects_unparseable_and_oversized() {
        assert!(NativeBackend::synthetic(
            &["mlp_b32_f32".to_string()]).is_err());
        assert!(NativeBackend::synthetic(
            &["gemm_n2048_t16_e1_f32".to_string()]).is_err());
    }
}
