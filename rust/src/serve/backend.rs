//! Serve-layer backends: the single execution abstraction behind every
//! shard.
//!
//! The paper's thesis — one implementation, tuned per backend — applied
//! to the serving plane: a [`Backend`] turns one [`WorkItem`] into one
//! [`Output`], and everything else (queueing, batching, caching,
//! metrics) lives once in the shard loop instead of once per subsystem.
//!
//! Three backend families exist today:
//!
//! * [`SimBackend`] — machine-model prediction for a simulated
//!   architecture (one shard per [`ArchId`]);
//! * [`NativeBackend`] — the `native:pjrt` shard: execution on the host
//!   via PJRT when the real `xla_extension` is linked, falling back to
//!   the **tuned packed host GEMM** (`gemm::kernel`) when device
//!   execution is unavailable (the vendored stub build, or a PJRT
//!   runtime failure at serve time). The fallback is reported
//!   explicitly in [`Output::Native`] — engine AND kernel label —
//!   never silently;
//! * [`ThreadpoolGemm`] — the `native:threadpool` shard: the tuned
//!   packed GEMM kernel fanned out over a [`ThreadPool`] in
//!   `mc`-aligned row-panel blocks, every run digest-checked against a
//!   sequentially-computed naive-reference oracle (memoized once per
//!   artifact). Native routing is therefore genuinely multi-shard:
//!   [`ShardKey::Native`] is a *named* key ([`NativeEngineId`]).
//!
//! Adding a fourth backend family means implementing [`Backend`] and
//! giving [`WorkItem`] a routing case — no new worker loop, no new
//! queue, no new metrics (see `lib.rs` crate docs and ROADMAP).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use std::sync::Mutex;

use crate::arch::ArchId;
use crate::autotune::{bucket_for, SharedTuningStore};
use crate::gemm::kernel::{self, KernelParams};
use crate::gemm::{metrics as gemm_metrics, verify, Epilogue, Precision};
use crate::model::{ModelSpec, NodeKind};
use crate::runtime::artifact::{ArtifactMeta, Manifest};
use crate::runtime::client::{LoadedKernel, Runtime};
use crate::sim::{Machine, TuningPoint};
use crate::tuner::SweepRecord;
use crate::util::prng;
use crate::util::threadpool::ThreadPool;

use super::fault::{FaultPlan, FaultSite};
use super::trace::{ActiveTrace, SpanKind};

/// Shared machine-model registry: one memoised [`Machine`] per
/// architecture. Lives here because every sim shard draws from it; the
/// coordinator's `Scheduler` re-exports it for backwards compatibility.
#[derive(Default)]
pub struct MachinePark {
    machines: Mutex<HashMap<ArchId, Arc<Machine>>>,
}

impl MachinePark {
    pub fn get(&self, arch: ArchId) -> Arc<Machine> {
        // the park is a memoisation cache: a poisoned registry
        // degrades to rebuilding the model per call, never a panic in
        // a sim shard (R2)
        match self.machines.lock() {
            Ok(mut g) => Arc::clone(g.entry(arch).or_insert_with(|| {
                Arc::new(Machine::for_arch(arch))
            })),
            Err(_) => Arc::new(Machine::for_arch(arch)),
        }
    }
}

/// Identity of a **native** shard — [`ShardKey::Native`] is a named
/// key, so native routing is genuinely multi-shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NativeEngineId {
    /// The single-owner PJRT shard (host reference-GEMM fallback when
    /// device execution is unavailable).
    Pjrt,
    /// The row-blocked host GEMM fanned out over an N-thread pool.
    Threadpool,
}

impl NativeEngineId {
    pub fn slug(&self) -> &'static str {
        match self {
            NativeEngineId::Pjrt => "pjrt",
            NativeEngineId::Threadpool => "threadpool",
        }
    }
}

/// What a [`WorkItem`] asks for (routing + execution payload).
#[derive(Debug, Clone, PartialEq)]
pub enum WorkPayload {
    /// Evaluate a tuning point on its architecture's machine model.
    Point(TuningPoint),
    /// Execute a lowered artifact on the named native shard.
    Artifact { id: String, engine: NativeEngineId },
    /// Explore kernel params for one `(dtype, shape bucket)` on the
    /// background `tune:explore` shard and commit the winner to the
    /// tuning store. Usually synthesized by the dispatcher when
    /// online tuning is enabled; submitting one explicitly is the
    /// programmatic warm-up path.
    Explore { dtype: Precision, bucket: u64 },
}

/// One unit of serveable work: a payload plus an optional **deadline**
/// and an optional **session tag**. A request whose deadline has passed
/// before execution starts may be shed by the serve layer (explicitly —
/// `ServeError::Overloaded`, never a silent drop) when the configured
/// shed policy says so. The session tag identifies the submitting
/// [`client::Session`](crate::client::Session): the dispatcher
/// round-robins burst routing across sessions (fair admission) and the
/// metrics keep per-session tallies.
#[derive(Debug, Clone)]
pub struct WorkItem {
    pub payload: WorkPayload,
    /// Latest instant at which starting execution is still useful.
    /// `None` = no deadline. Ignored by `ShedPolicy::None` and
    /// `ShedPolicy::RejectOverQuota`.
    pub deadline: Option<Instant>,
    /// Submitting session id (`None` for untagged callers — the
    /// legacy shims and direct `Serve::submit` users).
    pub session: Option<u64>,
    /// Flight-recorder trace id. Normally `None` at submission —
    /// minted at admission when tracing is on. Pipelines pre-assign
    /// one id (via [`WorkItem::with_trace`]) to every node so the
    /// whole DAG shares a trace lane in the export.
    pub trace_id: Option<u64>,
}

impl WorkItem {
    /// A tuning-point evaluation (simulated shards).
    pub fn point(p: TuningPoint) -> Self {
        Self { payload: WorkPayload::Point(p), deadline: None,
               session: None, trace_id: None }
    }

    /// An artifact execution on the default native shard
    /// ([`NativeEngineId::Pjrt`]).
    pub fn artifact(id: impl Into<String>) -> Self {
        Self::artifact_on(id, NativeEngineId::Pjrt)
    }

    /// An artifact execution on a *named* native shard.
    pub fn artifact_on(id: impl Into<String>, engine: NativeEngineId)
                       -> Self {
        Self {
            payload: WorkPayload::Artifact { id: id.into(), engine },
            deadline: None,
            session: None,
            trace_id: None,
        }
    }

    /// A bounded kernel-param exploration for `(dtype, bucket)` on the
    /// background tuning shard (see [`crate::autotune`]).
    pub fn explore(dtype: Precision, bucket: u64) -> Self {
        Self {
            payload: WorkPayload::Explore { dtype, bucket },
            deadline: None,
            session: None,
            trace_id: None,
        }
    }

    /// Tag with the submitting session (builder style).
    pub fn with_session(mut self, session: u64) -> Self {
        self.session = Some(session);
        self
    }

    /// Pre-assign a flight-recorder trace id (builder style). Like
    /// the session tag, the trace id is excluded from
    /// [`cache_key`](WorkItem::cache_key): it changes how an
    /// execution is *observed*, never what it computes.
    pub fn with_trace(mut self, id: u64) -> Self {
        self.trace_id = Some(id);
        self
    }

    /// Absolute deadline (builder style).
    pub fn with_deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Deadline relative to now (builder style).
    pub fn with_deadline_in(self, d: Duration) -> Self {
        self.with_deadline(Instant::now() + d)
    }

    /// Whether the deadline (if any) has passed at `now`.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now > d).unwrap_or(false)
    }

    /// Which shard serves this item.
    pub fn shard_key(&self) -> ShardKey {
        match &self.payload {
            WorkPayload::Point(p) => ShardKey::Sim(p.arch),
            WorkPayload::Artifact { engine, .. } => {
                ShardKey::Native(*engine)
            }
            WorkPayload::Explore { .. } => ShardKey::Tuner,
        }
    }

    /// Canonical key for batching and the result cache. Two items with
    /// equal keys are interchangeable executions; the deadline AND the
    /// session tag are deliberately excluded (they change *whether* /
    /// *for whom* an item runs, never *what* it computes — cross-session
    /// cache sharing is intended).
    pub fn cache_key(&self) -> String {
        match &self.payload {
            WorkPayload::Point(p) => format!("point:{p:?}"),
            WorkPayload::Artifact { id, .. } => format!("artifact:{id}"),
            WorkPayload::Explore { dtype, bucket } => {
                format!("explore:{}:{bucket}", dtype.dtype())
            }
        }
    }
}

/// Shard identity: one per simulated architecture plus one per named
/// native engine (the PJRT shard is single-owner — its client is
/// Rc-based; the threadpool shard owns its worker pool).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardKey {
    Sim(ArchId),
    Native(NativeEngineId),
    /// The background online-tuning shard (`tune:explore`): one
    /// worker, a hard-bounded queue, lowest effective priority — the
    /// dispatcher only ever feeds it with non-blocking pushes and
    /// sheds explorations rather than delaying serving traffic.
    Tuner,
}

impl ShardKey {
    pub fn label(&self) -> String {
        match self {
            ShardKey::Sim(a) => format!("sim:{}", a.slug()),
            ShardKey::Native(e) => format!("native:{}", e.slug()),
            ShardKey::Tuner => "tune:explore".to_string(),
        }
    }
}

/// Which engine actually served a native request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeEngine {
    Pjrt,
    HostGemm,
    /// Row-blocked host GEMM over the worker pool (`native:threadpool`).
    ThreadpoolGemm,
}

impl NativeEngine {
    /// Stable text form — load reports and the disk result cache key
    /// off it, so it round-trips through [`NativeEngine::parse`].
    pub fn slug(&self) -> &'static str {
        match self {
            NativeEngine::Pjrt => "pjrt",
            NativeEngine::HostGemm => "host-gemm",
            NativeEngine::ThreadpoolGemm => "threadpool-gemm",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "pjrt" => Some(NativeEngine::Pjrt),
            "host-gemm" => Some(NativeEngine::HostGemm),
            "threadpool-gemm" => Some(NativeEngine::ThreadpoolGemm),
            _ => None,
        }
    }
}

/// A completed execution.
#[derive(Debug, Clone)]
pub enum Output {
    /// Machine-model prediction (simulated shards).
    Sim {
        record: SweepRecord,
        /// Model-evaluation wall time in seconds.
        wall: f64,
    },
    /// Native execution (PJRT or host GEMM).
    Native {
        artifact_id: String,
        seconds: f64,
        gflops: Option<f64>,
        engine: NativeEngine,
        /// Which kernel produced the numbers: `pjrt` for device
        /// execution, `tuned{mc=..,nc=..,kc=..,mr=..,nr=..}` for the
        /// packed host kernel (suffixed `@store` when the params came
        /// from the tuning store rather than the built-in default),
        /// `naive` for the plain-loop reference — so tuning wins (and
        /// regressions) are attributable per reply.
        kernel: String,
    },
    /// A background exploration served by the `tune:explore` shard.
    Tuned {
        dtype: Precision,
        bucket: u64,
        /// Label of the winning [`KernelParams`].
        params: String,
        /// Measured GFLOP/s of the winner at the bucket size.
        gflops: f64,
        /// Kernel timings spent (0 when the bucket was already tuned).
        evals: usize,
        /// Exploration wall time in seconds.
        seconds: f64,
        /// Whether this run committed a new store entry (`false`: the
        /// bucket was already tuned by the time the job executed).
        committed: bool,
    },
}

/// Why one backend execution failed — structured so the serve layer's
/// recovery policies can discriminate. `Error` is an opaque (but
/// retryable) execution failure; `Corrupted` is an oracle-digest
/// mismatch attributable to ONE artifact, which the serve layer
/// surfaces as `ServeError::Corrupted` and feeds into the artifact
/// quarantine breaker. `From<String>` keeps `?` ergonomic for the many
/// string-producing helpers underneath.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendFailure {
    /// Opaque execution failure (message preserved verbatim).
    Error(String),
    /// The output failed the runtime oracle digest check: the compute
    /// ran, but produced bytes that disagree with the sequential
    /// reference for this artifact.
    Corrupted { artifact: String, detail: String },
}

impl std::fmt::Display for BackendFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
           -> std::fmt::Result {
        match self {
            BackendFailure::Error(m) => write!(f, "{m}"),
            BackendFailure::Corrupted { artifact, detail } => {
                write!(f, "corrupted output for {artifact}: {detail}")
            }
        }
    }
}

impl From<String> for BackendFailure {
    fn from(m: String) -> Self {
        BackendFailure::Error(m)
    }
}

impl From<&str> for BackendFailure {
    fn from(m: &str) -> Self {
        BackendFailure::Error(m.to_string())
    }
}

/// The execution abstraction every shard drives. Implementations are
/// created *inside* the shard thread (the PJRT client is not `Send`),
/// hence the `Send` factory type below rather than a `Send` bound here.
pub trait Backend {
    fn label(&self) -> String;
    fn run(&mut self, item: &WorkItem) -> Result<Output, BackendFailure>;

    /// [`run`](Backend::run) with the request's active trace in
    /// scope, so backends with internal stages (packing, oracle
    /// verification, tuning sweeps) can record sub-spans. The default
    /// ignores the trace — simple backends implement `run` only and
    /// still show up as the worker-recorded `execute` span.
    fn run_traced(&mut self, item: &WorkItem,
                  _trace: Option<&Arc<ActiveTrace>>)
                  -> Result<Output, BackendFailure> {
        self.run(item)
    }
}

/// Constructor executed on the shard thread. `FnMut` because the shard
/// worker's supervisor re-invokes it to respawn the backend after a
/// caught panic (see `serve::mod` worker supervision).
pub type BackendFactory =
    Box<dyn FnMut() -> Result<Box<dyn Backend>, String> + Send>;

// ---------------------------------------------------------------- sim --

/// Machine-model backend for one simulated architecture.
pub struct SimBackend {
    arch: ArchId,
    machine: Arc<Machine>,
}

impl SimBackend {
    pub fn new(arch: ArchId, park: &MachinePark) -> Self {
        Self { arch, machine: park.get(arch) }
    }
}

impl Backend for SimBackend {
    fn label(&self) -> String {
        ShardKey::Sim(self.arch).label()
    }

    fn run(&mut self, item: &WorkItem) -> Result<Output, BackendFailure> {
        match &item.payload {
            WorkPayload::Point(p) => {
                if p.arch != self.arch {
                    return Err(format!(
                        "routing bug: {} point on {} shard",
                        p.arch.label(), self.arch.label()).into());
                }
                let t0 = Instant::now();
                let pred = self.machine.predict(p);
                Ok(Output::Sim {
                    record: SweepRecord::new(*p, &pred),
                    wall: t0.elapsed().as_secs_f64(),
                })
            }
            WorkPayload::Artifact { id, .. } => Err(format!(
                "sim shard {} cannot execute artifact {id}",
                self.arch.label()).into()),
            WorkPayload::Explore { .. } => Err(format!(
                "sim shard {} cannot run tuning explorations",
                self.arch.label()).into()),
        }
    }
}

// ------------------------------------------------------------- native --

/// What the native backend knows about one artifact, independent of the
/// engine that ends up executing it.
#[derive(Debug, Clone)]
pub struct NativeSpec {
    pub id: String,
    pub n: u64,
    pub precision: Precision,
    pub flops: Option<u128>,
    /// Input seeds (a, b, c). `c` is unused for 2-input dot baselines.
    pub seeds: Vec<u64>,
    /// GEMM coefficients (from the manifest; 1.0/1.0 for synthetics).
    pub alpha: f64,
    pub beta: f64,
    /// Whether the host reference GEMM can legally reproduce this
    /// artifact (square shapes with known seeds).
    pub host_capable: bool,
}

/// Largest N the host fallback will multiply (O(N^3) on one thread).
/// Also the upper edge of the online tuner's bucket range — the
/// dispatcher never seeds an exploration for shapes the host kernels
/// cannot serve.
pub(crate) const HOST_GEMM_MAX_N: u64 = 1024;

/// One request's resolved kernel choice: blocking params, their
/// provenance, and (threadpool shard only) the store's measured
/// fan-out width.
#[derive(Debug, Clone, Copy)]
struct KernelSelection {
    params: KernelParams,
    from_store: bool,
    /// Measured-best worker fan-out for this bucket, when the store's
    /// exploration covered the thread axis. `None` = use the pool size.
    threads: Option<usize>,
}

/// Resolve the kernel blocking for one artifact spec: the tuning
/// store's measured winner for `(dtype, bucket)` when one exists for
/// this machine's fingerprint (sanitized to the actual N), the
/// built-in [`KernelParams::for_n`] default otherwise. Both native
/// backends share this so selection semantics (and the `@store` label
/// suffix) cannot drift apart. A poisoned store lock degrades to
/// defaults: selection must never take down the serving path.
fn params_for_spec(store: &Option<SharedTuningStore>, spec: &NativeSpec)
                   -> KernelSelection {
    params_for_bucket(store, spec.precision, spec.n as usize)
}

/// Bucket-level selection core shared by artifact specs and model
/// layer nodes (a layer selects by its GEMM output width `n` — the
/// same axis the buckets quantize).
fn params_for_bucket(store: &Option<SharedTuningStore>,
                     precision: Precision, n: usize) -> KernelSelection {
    if let Some(store) = store {
        if let Ok(g) = store.lock() {
            if let Some(e) = g.lookup(precision, bucket_for(n as u64)) {
                return KernelSelection {
                    params: e.params.sanitized(n),
                    from_store: true,
                    threads: e.threads.map(|t| t.max(1) as usize),
                };
            }
        }
    }
    KernelSelection { params: KernelParams::for_n(n), from_store: false,
                      threads: None }
}

/// The serve-layer kernel label for a blocking choice:
/// `tuned{mc=..,..}` for defaults, with an `@store` suffix when the
/// params came from the tuning store.
fn kernel_label(params: &KernelParams, from_store: bool) -> String {
    format!("tuned{{{}}}{}", params.label(),
            if from_store { "@store" } else { "" })
}

/// Whether the host reference GEMM can legally reproduce a manifest
/// artifact — the SAME predicate both native backends use, exposed so
/// mix builders (loadgen) never route a host-incapable artifact to the
/// threadpool shard.
pub(crate) fn meta_host_capable(meta: &ArtifactMeta) -> bool {
    spec_from_meta(meta).host_capable
}

/// Identity digest of one artifact spec — everything that determines
/// the bytes a native execution produces (id, shape, dtype, input
/// seeds, coefficients). The persistent result cache keys on it, so a
/// manifest change under the same artifact id reads as a miss instead
/// of replaying a stale result.
pub(crate) fn spec_digest(spec: &NativeSpec) -> String {
    format!("{}|n{}|{}|seeds{:x?}|a{}|b{}", spec.id, spec.n,
            spec.precision.dtype(), spec.seeds, spec.alpha, spec.beta)
}

/// Derive a [`NativeSpec`] from one manifest entry (shared by both
/// native backends — the PJRT shard and the threadpool shard must agree
/// on what "host capable" means).
pub(crate) fn spec_from_meta(meta: &ArtifactMeta) -> NativeSpec {
    let n = meta.n.unwrap_or(0);
    let square_inputs = meta.inputs.len() >= 2
        && meta.inputs.iter().all(|i| {
            i.shape.len() == 2
                && i.shape[0] as u64 == n
                && i.shape[1] as u64 == n
        });
    let host_capable = (meta.kind == "gemm" || meta.kind == "dot")
        && n > 0
        && n <= HOST_GEMM_MAX_N
        && square_inputs;
    NativeSpec {
        id: meta.id.clone(),
        n,
        precision: meta.precision,
        flops: meta.flops,
        seeds: meta.inputs.iter().map(|i| i.seed).collect(),
        alpha: meta.alpha,
        beta: meta.beta,
        host_capable,
    }
}

/// Manifest-less catalog over synthetic artifact ids (load testing
/// without `make artifacts`). Ids must parse — see [`parse_artifact_id`].
pub(crate) fn synthetic_catalog(ids: &[String])
                     -> Result<HashMap<String, NativeSpec>, String> {
    let mut catalog = HashMap::new();
    for id in ids {
        let (n, precision) = parse_artifact_id(id)
            .ok_or_else(|| format!(
                "cannot synthesize artifact id {id:?} (expected \
                 gemm_n<N>_t<T>_e<E>_<f32|f64> or dot_n<N>_<f32|f64> \
                 with default alpha/beta)"))?;
        if n > HOST_GEMM_MAX_N {
            return Err(format!(
                "synthetic artifact {id}: N={n} exceeds host \
                 fallback limit {HOST_GEMM_MAX_N}"));
        }
        // Real dot artifacts have 2 inputs (C is implicitly zero);
        // gemms have 3. Mirror that so the synthetic catalog
        // computes the same thing the manifest-backed one would.
        let n_inputs = if id.starts_with("dot_") { 2 } else { 3 };
        let spec = NativeSpec {
            id: id.clone(),
            n,
            precision,
            flops: Some(gemm_metrics::flops(n)),
            seeds: (0..n_inputs)
                .map(|k| prng::seed_for(id, k))
                .collect(),
            alpha: 1.0,
            beta: 1.0,
            host_capable: true,
        };
        catalog.insert(id.clone(), spec);
    }
    Ok(catalog)
}

enum HostInputs {
    F32 { a: Vec<f32>, b: Vec<f32>, c: Vec<f32> },
    F64 { a: Vec<f64>, b: Vec<f64>, c: Vec<f64> },
}

/// Regenerate an artifact's input matrices from its seeds (the shared
/// splitmix64 stream). `c` is zero for 2-input dot baselines, so any
/// beta is inert there.
fn build_host_inputs(spec: &NativeSpec) -> HostInputs {
    let n = spec.n as usize;
    let seed = |k: usize| {
        spec.seeds.get(k).copied()
            .unwrap_or_else(|| prng::seed_for(&spec.id, k as u64))
    };
    match spec.precision {
        Precision::F32 => HostInputs::F32 {
            a: prng::matrix_f32(seed(0), n, n),
            b: prng::matrix_f32(seed(1), n, n),
            c: if spec.seeds.len() >= 3 {
                prng::matrix_f32(seed(2), n, n)
            } else {
                vec![0.0; n * n]
            },
        },
        Precision::F64 => HostInputs::F64 {
            a: prng::matrix_f64(seed(0), n, n),
            b: prng::matrix_f64(seed(1), n, n),
            c: if spec.seeds.len() >= 3 {
                prng::matrix_f64(seed(2), n, n)
            } else {
                vec![0.0; n * n]
            },
        },
    }
}

struct PjrtEngine {
    runtime: Runtime,
    manifest: Manifest,
    kernels: HashMap<String, (LoadedKernel, Vec<xla::Literal>)>,
}

enum PjrtFailure {
    /// This artifact cannot be served over PJRT; the engine is fine.
    Artifact(String),
    /// Device execution is unavailable; fall back for all requests.
    Engine(String),
}

impl PjrtEngine {
    fn run(&mut self, id: &str) -> Result<f64, PjrtFailure> {
        if !self.kernels.contains_key(id) {
            let meta = self.manifest.by_id(id).ok_or_else(|| {
                PjrtFailure::Artifact(format!("unknown artifact {id}"))
            })?;
            let kernel =
                self.runtime.load(&self.manifest, meta).map_err(|e| {
                    PjrtFailure::Artifact(format!("load {id}: {e:#}"))
                })?;
            let inputs = kernel.make_inputs().map_err(|e| {
                PjrtFailure::Artifact(format!("inputs {id}: {e:#}"))
            })?;
            self.kernels.insert(id.to_string(), (kernel, inputs));
        }
        let (kernel, inputs) = self.kernels.get(id).expect("just inserted");
        let t0 = Instant::now();
        kernel
            .execute_only(inputs)
            .map_err(|e| PjrtFailure::Engine(format!("{e:#}")))?;
        Ok(t0.elapsed().as_secs_f64())
    }
}

/// The native shard's backend: PJRT first, host reference GEMM fallback.
pub struct NativeBackend {
    catalog: HashMap<String, NativeSpec>,
    pjrt: Option<PjrtEngine>,
    /// Set after the first engine-level PJRT failure; logged once.
    pjrt_dead: bool,
    host_inputs: HashMap<String, HostInputs>,
    /// Per-request kernel selection source (tuning store). `None` =
    /// always the built-in defaults.
    store: Option<SharedTuningStore>,
}

impl NativeBackend {
    /// Backend over a loaded artifacts manifest. PJRT client creation is
    /// attempted eagerly; failure leaves only the host fallback (and is
    /// reported per-request for artifacts the fallback cannot serve).
    pub fn from_manifest(manifest: Manifest) -> Self {
        let catalog = manifest
            .artifacts
            .iter()
            .map(|meta| (meta.id.clone(), spec_from_meta(meta)))
            .collect();
        let pjrt = match Runtime::new() {
            Ok(runtime) => Some(PjrtEngine {
                runtime,
                manifest,
                kernels: HashMap::new(),
            }),
            Err(e) => {
                eprintln!("[serve] PJRT unavailable ({e:#}); native \
                           shard uses the host reference GEMM");
                None
            }
        };
        Self { catalog, pjrt, pjrt_dead: false,
               host_inputs: HashMap::new(), store: None }
    }

    /// Manifest-less backend over synthetic artifact ids (load testing
    /// without `make artifacts`). Ids must parse — see
    /// [`parse_artifact_id`].
    pub fn synthetic(ids: &[String]) -> Result<Self, String> {
        Ok(Self { catalog: synthetic_catalog(ids)?, pjrt: None,
                  pjrt_dead: false, host_inputs: HashMap::new(),
                  store: None })
    }

    /// Attach a tuning store: the host fallback then runs each
    /// request with the store's measured-best params for its
    /// `(dtype, bucket)` (falling back to defaults on a miss), and
    /// labels such replies `…@store`.
    pub fn with_store(mut self, store: Option<SharedTuningStore>)
                      -> Self {
        self.store = store;
        self
    }

    pub fn artifact_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self.catalog.keys().cloned().collect();
        ids.sort();
        ids
    }

    /// One host execution of `spec` via the tuned packed kernel.
    /// Returns `(seconds, kernel label)`.
    fn host_run(&mut self, spec: &NativeSpec)
                -> Result<(f64, String), String> {
        if !spec.host_capable {
            return Err(format!(
                "artifact {} needs the PJRT runtime (host fallback only \
                 reproduces square gemm/dot with known seeds)",
                spec.id));
        }
        let n = spec.n as usize;
        // Per-request selection: the store's measured winner for this
        // (dtype, bucket) when present, defaults otherwise. The PJRT
        // shard's host fallback is single-threaded, so the selection's
        // fan-out axis is ignored here (threadpool shard only).
        let KernelSelection { params, from_store, .. } =
            params_for_spec(&self.store, spec);
        if !self.host_inputs.contains_key(&spec.id) {
            self.host_inputs.insert(spec.id.clone(),
                                    build_host_inputs(spec));
        }
        // 2-input dot baselines multiply into a zero C (so any beta is
        // inert); coefficients come from the manifest spec, 1/1 for
        // synthetics.
        let inputs = self.host_inputs.get(&spec.id).expect("just inserted");
        let t0 = Instant::now();
        match inputs {
            HostInputs::F32 { a, b, c } => {
                let out = kernel::gemm_f32_tuned(n, a, b, c,
                                                 spec.alpha as f32,
                                                 spec.beta as f32,
                                                 &params);
                std::hint::black_box(&out);
            }
            HostInputs::F64 { a, b, c } => {
                let out = kernel::gemm_f64_tuned(n, a, b, c, spec.alpha,
                                                 spec.beta, &params);
                std::hint::black_box(&out);
            }
        }
        Ok((t0.elapsed().as_secs_f64(),
            kernel_label(&params, from_store)))
    }
}

impl Backend for NativeBackend {
    fn label(&self) -> String {
        ShardKey::Native(NativeEngineId::Pjrt).label()
    }

    fn run(&mut self, item: &WorkItem) -> Result<Output, BackendFailure> {
        let id = match &item.payload {
            WorkPayload::Artifact { id, .. } => id,
            other => {
                return Err(format!(
                    "native shard cannot serve {other:?}").into());
            }
        };
        let spec = self
            .catalog
            .get(id)
            .ok_or_else(|| format!("unknown artifact {id}"))?
            .clone();

        // PJRT first (when linked and not known-dead) …
        if !self.pjrt_dead {
            if let Some(engine) = self.pjrt.as_mut() {
                match engine.run(id) {
                    Ok(seconds) => {
                        return Ok(Output::Native {
                            artifact_id: id.clone(),
                            seconds,
                            gflops: spec.flops.map(|f| {
                                f as f64 / seconds / 1e9
                            }),
                            engine: NativeEngine::Pjrt,
                            kernel: "pjrt".to_string(),
                        });
                    }
                    Err(PjrtFailure::Artifact(msg)) => {
                        return Err(msg.into());
                    }
                    Err(PjrtFailure::Engine(msg)) => {
                        eprintln!("[serve] PJRT execution failed ({msg}); \
                                   switching native shard to the host \
                                   reference GEMM");
                        self.pjrt_dead = true;
                    }
                }
            }
        }

        // … tuned host GEMM otherwise.
        let (seconds, kernel) = self.host_run(&spec)?;
        Ok(Output::Native {
            artifact_id: id.clone(),
            seconds,
            gflops: spec.flops.map(|f| f as f64 / seconds / 1e9),
            engine: NativeEngine::HostGemm,
            kernel,
        })
    }
}

// --------------------------------------------------------- threadpool --

/// Relative digest tolerance for the runtime oracle check. The tuned
/// kernel accumulates each element in the same ascending-k order as the
/// naive `_rows` oracle (bit-identical on IEEE targets — see
/// `gemm::kernel` docs), and the chunk-ordered reduction matches the
/// oracle's association, so these bounds are belt-and-braces headroom,
/// not a correctness crutch.
fn digest_rtol(p: Precision) -> f64 {
    match p {
        Precision::F32 => 1e-4,
        Precision::F64 => 1e-10,
    }
}

/// Reference digest of one artifact's output, computed **sequentially**
/// by the naive `_rows` reference, ONCE per artifact at input-setup
/// time (memoized — the seeds are deterministic, so it can never
/// change; `ThreadpoolGemm::oracle_builds` counts the computations so
/// tests can pin the once-per-artifact invariant). `sum` is compared
/// against every parallel run (scaled by `abs_sum` — the inputs are
/// signed-uniform, so the signed sum's own magnitude is a bad
/// yardstick).
struct OracleDigest {
    sum: f64,
    abs_sum: f64,
}

/// One model-plane catalog entry: which layer of which model a
/// synthetic node id (`mlp_b64_f32#L0`, `…+strict`, `…!gemm`, `…!act`)
/// executes, and how (see [`crate::model::NodeKind`]).
#[derive(Clone)]
struct ModelJob {
    spec: Arc<ModelSpec>,
    layer: usize,
    kind: NodeKind,
}

/// Memoized strict forward state of one model layer: `pre` is the
/// bias-only affine output (the unfused GEMM stage's reference), `post`
/// the post-activation output (the layer's actual value — equal to
/// `pre` on non-activating layers). Both come from the sequential naive
/// kernel, so they are the per-node oracle AND the next layer's input:
/// every tier chains through the *strict* previous layer, which keeps
/// each node independently verifiable and cacheable.
#[derive(Clone)]
struct ModelLayer {
    pre: Arc<Vec<f32>>,
    post: Arc<Vec<f32>>,
}

/// Build the model-node catalog from a manifest's validated `mlp`
/// entries: one [`ModelJob`] per (layer × node kind). Models the plane
/// cannot serve (non-f32) are skipped with a printed reason — GEMM
/// serving must not fail because an exotic model rode in the manifest.
fn model_catalog(manifest: &Manifest) -> HashMap<String, ModelJob> {
    let mut jobs = HashMap::new();
    for meta in &manifest.artifacts {
        if meta.model.is_none() {
            continue;
        }
        let spec = match ModelSpec::from_meta(meta) {
            Ok(spec) => Arc::new(spec),
            Err(e) => {
                eprintln!("[serve] model plane skips {}: {e}", meta.id);
                continue;
            }
        };
        for (l, layer) in spec.layers.iter().enumerate() {
            for kind in [NodeKind::Fused, NodeKind::Strict,
                         NodeKind::GemmOnly, NodeKind::Activation] {
                if kind == NodeKind::Activation && !layer.activation {
                    continue;
                }
                jobs.insert(spec.node_id(l, kind),
                            ModelJob { spec: Arc::clone(&spec),
                                       layer: l, kind });
            }
        }
    }
    jobs
}

/// The `native:threadpool` shard's backend: the **tuned packed GEMM
/// kernel** (`gemm::kernel`) fanned out over an owned [`ThreadPool`] in
/// `mc`-aligned row-panel blocks, with every run's output digest
/// checked against the sequential naive-reference oracle. This is the
/// second *named* native shard — it exists so native routing is real
/// multi-shard traffic, not a single hot spot.
pub struct ThreadpoolGemm {
    catalog: HashMap<String, NativeSpec>,
    pool: ThreadPool,
    // Per-backend input cache. The PJRT shard's host fallback keeps its
    // own copy of the same matrices for shared artifact ids — accepted
    // duplication: shards are deliberately share-nothing (each backend
    // lives on its own thread; a cross-shard input store would couple
    // their lifetimes for ~MBs of regenerable data).
    inputs: HashMap<String, Arc<HostInputs>>,
    /// Oracle digests keyed by `(artifact, mc, fanout)`: the digest's
    /// chunked reduction order depends on the fan-out chunking, which
    /// follows the kernel's `mc` AND the effective worker fan-out
    /// (store-driven thread counts change the chunk boundaries) — when
    /// the tuning store commits a different blocking or fan-out for a
    /// bucket, the artifact gets ONE more sequential oracle build under
    /// the new chunking (bounded: params change at most once per store
    /// commit, not per request).
    oracles: HashMap<(String, usize, usize), OracleDigest>,
    /// How many oracle digests were ever computed — exactly one per
    /// distinct `(artifact, blocking)` served, never one per request
    /// (the O(N³) sequential reference must not sit on the request
    /// path).
    oracle_builds: usize,
    /// Per-request kernel selection source (tuning store). `None` =
    /// always the built-in defaults.
    store: Option<SharedTuningStore>,
    /// Fault-injection plan (chaos testing): when the `CorruptOutput`
    /// site fires, the computed digest is perturbed *before* the
    /// oracle comparison — corruption is **detected by the real
    /// check**, never synthesized as a pre-made error.
    plan: Option<Arc<FaultPlan>>,
    /// Model-plane node catalog (synthetic `<model>#L<k>…` ids), built
    /// from the manifest's `mlp` entries; empty for synthetic backends.
    models: HashMap<String, ModelJob>,
    /// Memoized strict layer state per `(model, layer)` — the model
    /// analogue of `oracles`, built sequentially at most once per
    /// layer (counted in `oracle_builds`).
    model_layers: HashMap<(String, usize), ModelLayer>,
    /// Memoized batch inputs per model id (regenerated from seeds).
    model_inputs: HashMap<String, Arc<Vec<f32>>>,
    /// Memoized `(weight, bias)` tensors per `(model, layer)`.
    model_weights: HashMap<(String, usize), Arc<(Vec<f32>, Vec<f32>)>>,
}

impl ThreadpoolGemm {
    /// Backend over a loaded manifest; `threads` worker threads
    /// (0 = host-sized pool). Artifacts the host GEMM cannot legally
    /// reproduce stay in the catalog and fail per-request with an
    /// explicit "needs PJRT" error, mirroring the PJRT shard's
    /// fallback guard.
    pub fn from_manifest(manifest: &Manifest, threads: usize) -> Self {
        let catalog = manifest
            .artifacts
            .iter()
            .map(|meta| (meta.id.clone(), spec_from_meta(meta)))
            .collect();
        let mut backend = Self::with_catalog(catalog, threads);
        // Model plane: every mlp entry contributes per-layer synthetic
        // nodes — served, verified and cached like any artifact.
        backend.models = model_catalog(manifest);
        backend
    }

    /// Manifest-less backend over synthetic artifact ids.
    pub fn synthetic(ids: &[String], threads: usize)
                     -> Result<Self, String> {
        Ok(Self::with_catalog(synthetic_catalog(ids)?, threads))
    }

    fn with_catalog(catalog: HashMap<String, NativeSpec>,
                    threads: usize) -> Self {
        let pool = if threads == 0 {
            ThreadPool::host_sized()
        } else {
            ThreadPool::new(threads)
        };
        Self { catalog, pool, inputs: HashMap::new(),
               oracles: HashMap::new(), oracle_builds: 0, store: None,
               plan: None, models: HashMap::new(),
               model_layers: HashMap::new(),
               model_inputs: HashMap::new(),
               model_weights: HashMap::new() }
    }

    /// Attach a tuning store: each request then runs with the store's
    /// measured-best params for its `(dtype, bucket)` (defaults on a
    /// miss), labelled `…@store`. The digest oracle follows the
    /// selected blocking (see the `oracles` field).
    pub fn with_store(mut self, store: Option<SharedTuningStore>)
                      -> Self {
        self.store = store;
        self
    }

    /// Attach a fault-injection plan (see the `plan` field): output
    /// corruption then fires at the plan's `CorruptOutput` rate and is
    /// caught by the genuine oracle digest check.
    pub fn with_fault(mut self, plan: Option<Arc<FaultPlan>>) -> Self {
        self.plan = plan;
        self
    }

    pub fn threads(&self) -> usize {
        self.pool.size()
    }

    pub fn artifact_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self.catalog.keys().cloned().collect();
        ids.sort();
        ids
    }

    /// How many sequential oracle digests this backend has computed —
    /// at most one per distinct `(artifact, blocking)`, regardless of
    /// request count (asserted in tests).
    pub fn oracle_builds(&self) -> usize {
        self.oracle_builds
    }

    /// Effective worker fan-out for one request: the store's measured
    /// thread count when the exploration covered the fan-out axis
    /// (clamped to the pool — the pool never grows per request),
    /// otherwise the full pool.
    fn fanout(&self, threads: Option<usize>) -> usize {
        threads.map(|t| t.clamp(1, self.pool.size()))
            .unwrap_or_else(|| self.pool.size())
    }

    /// Row partition for the tuned-kernel fan-out: every participating
    /// worker gets ~2 chunks so a slow chunk cannot serialize the tail.
    /// When the per-worker share covers at least one `mc` panel, chunks
    /// are rounded DOWN to whole panels (boundaries on the kernel's
    /// natural blocking); below that, small chunks win — shrinking `mb`
    /// inside the kernel is cheap, collapsing the fan-out to one worker
    /// is not. `fanout` is the participating-worker count (the store's
    /// measured thread axis, or the pool size).
    fn chunks(&self, n: usize, mc: usize, fanout: usize)
              -> Vec<(usize, usize)> {
        let jobs = (fanout.max(1) * 2).clamp(1, n.max(1));
        let per = n.div_ceil(jobs).max(1);
        let per = if per >= mc { (per / mc) * mc } else { per };
        (0..n)
            .step_by(per)
            .map(|r0| (r0, (r0 + per).min(n)))
            .collect()
    }

    /// Ensure the deterministic input matrices exist for `spec`.
    fn ensure_inputs(&mut self, spec: &NativeSpec) {
        if !self.inputs.contains_key(&spec.id) {
            self.inputs.insert(spec.id.clone(),
                               Arc::new(build_host_inputs(spec)));
        }
    }

    /// Ensure the sequential reference digest exists for `spec` under
    /// the chunking that `mc` implies.
    ///
    /// Cold-start cost, deliberately accepted: the oracle is a full
    /// **sequential** GEMM (its independence from the pool fan-out is
    /// the whole point of the check), run ONCE per `(artifact,
    /// blocking)` on the shard worker — the same first-touch stall
    /// shape as the PJRT shard's kernel load/compile, repeated at most
    /// once more when the tuning store commits a new blocking for the
    /// artifact's bucket. Under `ShedPolicy::ShedExpired`,
    /// tight-deadline requests queued behind a cold large artifact may
    /// be shed during this warmup; that is the configured overload
    /// behavior (the shard IS saturated), bounded per artifact
    /// lifetime.
    fn ensure_oracle(&mut self, spec: &NativeSpec, mc: usize,
                     fanout: usize) {
        let key = (spec.id.clone(), mc, fanout);
        if self.oracles.contains_key(&key) {
            return;
        }
        let inputs = Arc::clone(self.inputs.get(&spec.id)
                                    .expect("ensure_inputs first"));
        let n = spec.n as usize;
        // Sequential NAIVE oracle (the plain `_rows` reference — the
        // tuned kernel must never verify itself against itself),
        // digested with the SAME row chunking the parallel path uses,
        // so the reductions associate identically.
        let chunks = self.chunks(n, mc, fanout);
        let (sum, abs_sum) = match &*inputs {
            HostInputs::F32 { a, b, c } => {
                let full = verify::gemm_f32_rows(n, 0, n, a, b, c,
                                                 spec.alpha as f32,
                                                 spec.beta as f32);
                digest_chunked(&chunks, n, |lo, hi| {
                    sum_abs_f32(&full[lo..hi])
                })
            }
            HostInputs::F64 { a, b, c } => {
                let full = verify::gemm_f64_rows(n, 0, n, a, b, c,
                                                 spec.alpha, spec.beta);
                digest_chunked(&chunks, n, |lo, hi| {
                    sum_abs_f64(&full[lo..hi])
                })
            }
        };
        self.oracle_builds += 1;
        self.oracles.insert(key, OracleDigest { sum, abs_sum });
    }

    /// One parallel run of the tuned kernel under `params` over
    /// `mc`-aligned row-panel blocks, fanned across `fanout` workers:
    /// returns (seconds, sum, abs_sum) of the output.
    fn par_run(&self, spec: &NativeSpec, params: &KernelParams,
               fanout: usize) -> Result<(f64, f64, f64), String> {
        let n = spec.n as usize;
        let params = *params;
        let inputs = Arc::clone(self.inputs.get(&spec.id)
                                    .expect("ensure_inputs first"));
        let chunks = self.chunks(n, params.mc, fanout);
        let t0 = Instant::now();
        let results: Vec<Result<(f64, f64), String>> =
            match &*inputs {
                HostInputs::F32 { .. } => {
                    let (alpha, beta) =
                        (spec.alpha as f32, spec.beta as f32);
                    let inp = Arc::clone(&inputs);
                    self.pool.try_map(chunks, move |(r0, r1)| {
                        let HostInputs::F32 { a, b, c } = &*inp else {
                            unreachable!("precision checked above")
                        };
                        let rows = kernel::gemm_f32_tuned_rows(
                            n, r0, r1, a, b, c, alpha, beta, &params);
                        sum_abs_f32(&rows)
                    })
                }
                HostInputs::F64 { .. } => {
                    let (alpha, beta) = (spec.alpha, spec.beta);
                    let inp = Arc::clone(&inputs);
                    self.pool.try_map(chunks, move |(r0, r1)| {
                        let HostInputs::F64 { a, b, c } = &*inp else {
                            unreachable!("precision checked above")
                        };
                        let rows = kernel::gemm_f64_tuned_rows(
                            n, r0, r1, a, b, c, alpha, beta, &params);
                        sum_abs_f64(&rows)
                    })
                }
            };
        let seconds = t0.elapsed().as_secs_f64();
        let (mut sum, mut abs_sum) = (0.0f64, 0.0f64);
        for r in results {
            let (s, a) = r.map_err(|msg| format!(
                "threadpool GEMM job panicked on {}: {msg}", spec.id))?;
            sum += s;
            abs_sum += a;
        }
        Ok((seconds, sum, abs_sum))
    }

    // ---------------------------------------------------- model plane --

    /// Memoized batch input tensor of one model.
    fn ensure_model_input(&mut self, spec: &Arc<ModelSpec>)
                          -> Arc<Vec<f32>> {
        if let Some(x) = self.model_inputs.get(&spec.id) {
            return Arc::clone(x);
        }
        let x = Arc::new(spec.input_x());
        self.model_inputs.insert(spec.id.clone(), Arc::clone(&x));
        x
    }

    /// Memoized `(weight, bias)` tensors of one layer.
    fn ensure_model_weights(&mut self, spec: &Arc<ModelSpec>,
                            layer: usize) -> Arc<(Vec<f32>, Vec<f32>)> {
        let key = (spec.id.clone(), layer);
        if let Some(w) = self.model_weights.get(&key) {
            return Arc::clone(w);
        }
        let w = Arc::new((spec.weight(layer), spec.bias(layer)));
        self.model_weights.insert(key, Arc::clone(&w));
        w
    }

    /// Memoized strict forward of `spec` through `layer`: sequential
    /// naive kernel, deterministic activation — the model analogue of
    /// [`ThreadpoolGemm::ensure_oracle`], built at most once per layer
    /// (counted in `oracle_builds`; the O(m·n·k) sequential reference
    /// must never sit on the warm request path). Building the FINAL
    /// layer also cross-checks the python manifest digest once, so a
    /// drifted manifest is caught at first serve, not never.
    fn ensure_model_layer(&mut self, spec: &Arc<ModelSpec>,
                          layer: usize) -> Result<ModelLayer, String> {
        let key = (spec.id.clone(), layer);
        if let Some(l) = self.model_layers.get(&key) {
            return Ok(l.clone());
        }
        let input = if layer == 0 {
            self.ensure_model_input(spec)
        } else {
            self.ensure_model_layer(spec, layer - 1)?.post
        };
        let pre = Arc::new(spec.layer_preact(&input, layer));
        let post = if spec.layers[layer].activation {
            let mut act = (*pre).clone();
            ModelSpec::activate(&mut act);
            Arc::new(act)
        } else {
            Arc::clone(&pre)
        };
        if layer + 1 == spec.layers.len() {
            spec.check_final_digest(&post)?;
        }
        self.oracle_builds += 1;
        let entry = ModelLayer { pre, post };
        self.model_layers.insert(key, entry.clone());
        Ok(entry)
    }

    /// Record one model node's oracle digest under the given chunking
    /// (key `(node, mc, fanout)`; sequential kinds use `(0, 0)` with a
    /// single whole-output chunk). Cheap re-summation of the memoized
    /// strict state — not counted as an oracle *build*.
    fn ensure_model_oracle(&mut self, node_id: &str, reference: &[f32],
                           cols: usize, chunks: &[(usize, usize)],
                           mc: usize, fanout: usize) {
        let key = (node_id.to_string(), mc, fanout);
        if self.oracles.contains_key(&key) {
            return;
        }
        let (sum, abs_sum) = digest_chunked(chunks, cols, |lo, hi| {
            sum_abs_f32(&reference[lo..hi])
        });
        self.oracles.insert(key, OracleDigest { sum, abs_sum });
    }

    /// Verify one model node's chunk-ordered output digest against its
    /// recorded oracle: Verify span, chaos `CorruptOutput` injection
    /// through the REAL check, `Corrupted` on mismatch — byte-for-byte
    /// the GEMM artifact discipline, applied per layer node.
    fn verify_model(&mut self, id: &str, key: (usize, usize),
                    mut sum: f64, abs_sum: f64,
                    trace: Option<&Arc<ActiveTrace>>)
                    -> Result<(), BackendFailure> {
        let mut ver = trace.map(|t| t.span(SpanKind::Verify));
        let oracle = self.oracles
            .get(&(id.to_string(), key.0, key.1))
            .expect("ensure_model_oracle first");
        if self.plan.as_ref()
            .is_some_and(|p| p.should_fire(FaultSite::CorruptOutput))
        {
            // Chaos injection: shift the digest by a full abs-sum so
            // the comparison below MUST trip.
            sum += oracle.abs_sum.max(abs_sum).max(1.0);
            if let Some(g) = ver.as_mut() {
                g.fault(FaultSite::CorruptOutput);
            }
        }
        let scale = oracle.abs_sum.max(abs_sum).max(1.0);
        let rtol = digest_rtol(Precision::F32);
        let ok = (sum - oracle.sum).abs() <= rtol * scale;
        if let Some(g) = ver.as_mut() {
            g.attr("ok", ok.to_string());
        }
        drop(ver);
        if !ok {
            return Err(BackendFailure::Corrupted {
                artifact: id.to_string(),
                detail: format!(
                    "model node digest mismatch: sum {sum} vs oracle \
                     {} (scale {scale}, rtol {rtol})", oracle.sum),
            });
        }
        Ok(())
    }

    /// Execute one model-plane node. Parallel kinds (fused layer,
    /// unfused GEMM stage) run the tuned rectangular kernel with the
    /// epilogue fused into the store loop, row-chunked over the pool
    /// under store-selected params; sequential kinds (strict layer,
    /// unfused activation pass) run inline on the shard worker. Every
    /// kind chains through the memoized strict previous layer and is
    /// digest-verified against the memoized strict state of its own
    /// layer.
    fn run_model(&mut self, id: &str, job: &ModelJob,
                 trace: Option<&Arc<ActiveTrace>>)
                 -> Result<Output, BackendFailure> {
        let spec = Arc::clone(&job.spec);
        let l = job.layer;
        let (m, n, k) = (spec.layers[l].m, spec.layers[l].n,
                         spec.layers[l].k);
        // A failed strict build (manifest digest drift) is attributed
        // to the REQUESTED node, so quarantine keys correctly.
        let corrupted = |detail: String| BackendFailure::Corrupted {
            artifact: id.to_string(),
            detail,
        };
        let flops = spec.layers[l].flops();
        let epi_label =
            if spec.layers[l].activation { "bias+tanh" } else { "bias" };
        match job.kind {
            NodeKind::Fused | NodeKind::GemmOnly => {
                let fused = job.kind == NodeKind::Fused;
                let sel = params_for_bucket(&self.store,
                                            Precision::F32, n);
                let (params, from_store) = (sel.params, sel.from_store);
                let fanout = self.fanout(sel.threads);
                // Pack span: tensor materialization + the strict
                // oracle build — the model's first-touch cost.
                let pack = trace.map(|t| t.span(SpanKind::Pack));
                let input = if l == 0 {
                    self.ensure_model_input(&spec)
                } else {
                    self.ensure_model_layer(&spec, l - 1)
                        .map_err(&corrupted)?
                        .post
                };
                let wb = self.ensure_model_weights(&spec, l);
                let state = self.ensure_model_layer(&spec, l)
                    .map_err(&corrupted)?;
                let reference: &[f32] =
                    if fused { &state.post } else { &state.pre };
                let chunks = self.chunks(m, params.mc, fanout);
                self.ensure_model_oracle(id, reference, n, &chunks,
                                         params.mc, fanout);
                drop(pack);
                let epi = spec.epilogue(l, fused);
                let label = format!("{}+{}",
                                    kernel_label(&params, from_store),
                                    epi.label());
                let epi = Arc::new(epi);
                let (alpha, beta) = (spec.alpha, spec.beta);
                let t0 = Instant::now();
                let results = self.pool.try_map(chunks,
                                                move |(r0, r1)| {
                    let out = kernel::gemm_f32_tuned_rect_rows(
                        m, n, k, r0, r1, &input, &wb.0, alpha, beta,
                        &epi, &params);
                    sum_abs_f32(&out)
                });
                let seconds = t0.elapsed().as_secs_f64();
                let (mut sum, mut abs_sum) = (0.0f64, 0.0f64);
                for r in results {
                    let (s, a) = r.map_err(|msg| format!(
                        "model node {id} panicked: {msg}"))?;
                    sum += s;
                    abs_sum += a;
                }
                self.verify_model(id, (params.mc, fanout), sum,
                                  abs_sum, trace)?;
                Ok(Output::Native {
                    artifact_id: id.to_string(),
                    seconds,
                    gflops: Some(flops as f64 / seconds / 1e9),
                    engine: NativeEngine::ThreadpoolGemm,
                    kernel: label,
                })
            }
            NodeKind::Strict => {
                let pack = trace.map(|t| t.span(SpanKind::Pack));
                let input = if l == 0 {
                    self.ensure_model_input(&spec)
                } else {
                    self.ensure_model_layer(&spec, l - 1)
                        .map_err(&corrupted)?
                        .post
                };
                let state = self.ensure_model_layer(&spec, l)
                    .map_err(&corrupted)?;
                self.ensure_model_oracle(id, &state.post, n,
                                         &[(0, m)], 0, 0);
                drop(pack);
                // Recompute the layer per request (honest timing); the
                // memoized copy above is the verification oracle.
                let t0 = Instant::now();
                let out = spec.layer_strict(&input, l);
                let seconds = t0.elapsed().as_secs_f64();
                let (sum, abs_sum) = sum_abs_f32(&out);
                self.verify_model(id, (0, 0), sum, abs_sum, trace)?;
                Ok(Output::Native {
                    artifact_id: id.to_string(),
                    seconds,
                    gflops: Some(flops as f64 / seconds / 1e9),
                    engine: NativeEngine::ThreadpoolGemm,
                    kernel: format!("strict+{epi_label}"),
                })
            }
            NodeKind::Activation => {
                let pack = trace.map(|t| t.span(SpanKind::Pack));
                let state = self.ensure_model_layer(&spec, l)
                    .map_err(&corrupted)?;
                self.ensure_model_oracle(id, &state.post, n,
                                         &[(0, m)], 0, 0);
                drop(pack);
                let t0 = Instant::now();
                let mut out = (*state.pre).clone();
                ModelSpec::activate(&mut out);
                let seconds = t0.elapsed().as_secs_f64();
                let (sum, abs_sum) = sum_abs_f32(&out);
                self.verify_model(id, (0, 0), sum, abs_sum, trace)?;
                Ok(Output::Native {
                    artifact_id: id.to_string(),
                    seconds,
                    // an elementwise pass has no meaningful GEMM rate
                    gflops: None,
                    engine: NativeEngine::ThreadpoolGemm,
                    kernel: "det-tanh".to_string(),
                })
            }
        }
    }
}

fn sum_abs_f32(v: &[f32]) -> (f64, f64) {
    let mut s = 0.0f64;
    let mut a = 0.0f64;
    for x in v {
        s += *x as f64;
        a += (*x as f64).abs();
    }
    (s, a)
}

fn sum_abs_f64(v: &[f64]) -> (f64, f64) {
    let mut s = 0.0f64;
    let mut a = 0.0f64;
    for x in v {
        s += *x;
        a += x.abs();
    }
    (s, a)
}

/// Digest a full row-major output using the given row chunks (element
/// ranges derived per chunk), reducing chunk digests in chunk order —
/// the same association the parallel path produces.
fn digest_chunked<F>(chunks: &[(usize, usize)], n: usize, digest: F)
                     -> (f64, f64)
where
    F: Fn(usize, usize) -> (f64, f64),
{
    let (mut sum, mut abs_sum) = (0.0f64, 0.0f64);
    for &(r0, r1) in chunks {
        let (s, a) = digest(r0 * n, r1 * n);
        sum += s;
        abs_sum += a;
    }
    (sum, abs_sum)
}

impl Backend for ThreadpoolGemm {
    fn label(&self) -> String {
        ShardKey::Native(NativeEngineId::Threadpool).label()
    }

    fn run(&mut self, item: &WorkItem) -> Result<Output, BackendFailure> {
        self.run_traced(item, None)
    }

    fn run_traced(&mut self, item: &WorkItem,
                  trace: Option<&Arc<ActiveTrace>>)
                  -> Result<Output, BackendFailure> {
        let id = match &item.payload {
            WorkPayload::Artifact { id, .. } => id,
            other => {
                return Err(format!(
                    "threadpool shard cannot serve {other:?}").into());
            }
        };
        // Model-plane nodes first: synthetic `<model>#L<k>…` ids never
        // collide with manifest artifact ids (`#` cannot appear there).
        if let Some(job) = self.models.get(id.as_str()).cloned() {
            return self.run_model(id, &job, trace);
        }
        let spec = self
            .catalog
            .get(id)
            .ok_or_else(|| format!("unknown artifact {id}"))?
            .clone();
        if !spec.host_capable {
            return Err(format!(
                "artifact {} needs the PJRT runtime (threadpool shard \
                 only reproduces square gemm/dot with known seeds)",
                spec.id).into());
        }
        // Per-request selection: store winner for this (dtype, bucket)
        // when present, defaults otherwise — blocking params AND the
        // measured fan-out width. The oracle digest follows both
        // (chunking depends on mc and the participating worker count).
        let sel = params_for_spec(&self.store, &spec);
        let (params, from_store) = (sel.params, sel.from_store);
        let fanout = self.fanout(sel.threads);
        // Pack span: input materialization + the sequential oracle
        // build — near-zero when warm, the dominant first-touch cost
        // when cold (exactly what a slow-exemplar trace should show).
        let pack = trace.map(|t| t.span(SpanKind::Pack));
        self.ensure_inputs(&spec);
        self.ensure_oracle(&spec, params.mc, fanout);
        drop(pack);
        let (seconds, mut sum, abs_sum) =
            self.par_run(&spec, &params, fanout)?;
        // Runtime oracle check: every served result is digest-verified
        // against the sequential reference computed at setup.
        let mut ver = trace.map(|t| t.span(SpanKind::Verify));
        let oracle = self.oracles.get(&(id.clone(), params.mc, fanout))
            .expect("ensure_oracle first");
        if self.plan.as_ref()
            .is_some_and(|p| p.should_fire(FaultSite::CorruptOutput))
        {
            // Chaos injection: shift the digest by a full abs-sum so
            // the comparison below MUST trip — the detection path is
            // the production one, only the corruption is synthetic.
            sum += oracle.abs_sum.max(abs_sum).max(1.0);
            if let Some(g) = ver.as_mut() {
                g.fault(FaultSite::CorruptOutput);
            }
        }
        let scale = oracle.abs_sum.max(abs_sum).max(1.0);
        let rtol = digest_rtol(spec.precision);
        let ok = (sum - oracle.sum).abs() <= rtol * scale;
        if let Some(g) = ver.as_mut() {
            g.attr("ok", ok.to_string());
        }
        drop(ver);
        if !ok {
            return Err(BackendFailure::Corrupted {
                artifact: id.clone(),
                detail: format!(
                    "threadpool GEMM digest mismatch: sum {sum} vs \
                     oracle {} (scale {scale}, rtol {rtol})",
                    oracle.sum),
            });
        }
        Ok(Output::Native {
            artifact_id: id.clone(),
            seconds,
            gflops: spec.flops.map(|f| f as f64 / seconds / 1e9),
            engine: NativeEngine::ThreadpoolGemm,
            kernel: kernel_label(&params, from_store),
        })
    }
}

/// Parse a synthetic artifact id of the forms the AOT path emits:
/// `gemm_n<N>_t<T>_e<E>_<f32|f64>` or `dot_n<N>_<f32|f64>`. Returns
/// `(n, precision)`, or `None` for anything else — including
/// alpha/beta-suffixed ids (`…_a1.5_b0.5`), which the host fallback must
/// not silently misreproduce with default coefficients.
pub fn parse_artifact_id(id: &str) -> Option<(u64, Precision)> {
    let toks: Vec<&str> = id.split('_').collect();
    if toks.len() < 3 || (toks[0] != "gemm" && toks[0] != "dot") {
        return None;
    }
    let n: u64 = toks[1].strip_prefix('n')?.parse().ok()?;
    let precision = Precision::parse(toks.last()?)?;
    // middle tokens must be t<digits> / e<digits> only
    for t in &toks[2..toks.len() - 1] {
        let bytes = t.as_bytes();
        if bytes.len() < 2
            || !(bytes[0] == b't' || bytes[0] == b'e')
            || !bytes[1..].iter().all(u8::is_ascii_digit)
        {
            return None;
        }
    }
    if n == 0 {
        return None;
    }
    Some((n, precision))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CompilerId;

    #[test]
    fn work_item_routing_and_keys() {
        let p = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 1024, 64, 1);
        let w = WorkItem::point(p);
        assert_eq!(w.shard_key(), ShardKey::Sim(ArchId::Knl));
        let a = WorkItem::artifact("dot_n128_f32");
        assert_eq!(a.shard_key(),
                   ShardKey::Native(NativeEngineId::Pjrt));
        let tp = WorkItem::artifact_on("dot_n128_f32",
                                       NativeEngineId::Threadpool);
        assert_eq!(tp.shard_key(),
                   ShardKey::Native(NativeEngineId::Threadpool));
        assert_ne!(w.cache_key(), a.cache_key());
        assert_eq!(a.cache_key(),
                   WorkItem::artifact("dot_n128_f32").cache_key());
        // the cache key ignores the engine (per-shard caches) AND the
        // deadline (it gates execution, not the result)
        assert_eq!(a.cache_key(), tp.cache_key());
        assert_eq!(a.cache_key(),
                   WorkItem::artifact("dot_n128_f32")
                       .with_deadline_in(Duration::from_millis(5))
                       .cache_key());
        assert_eq!(ShardKey::Native(NativeEngineId::Pjrt).label(),
                   "native:pjrt");
        assert_eq!(ShardKey::Native(NativeEngineId::Threadpool).label(),
                   "native:threadpool");
    }

    #[test]
    fn deadlines_expire_exactly_when_passed() {
        let now = Instant::now();
        let none = WorkItem::artifact("dot_n64_f32");
        assert!(!none.expired(now), "no deadline never expires");
        let later = none.clone()
            .with_deadline(now + Duration::from_secs(3600));
        assert!(!later.expired(now));
        let past = WorkItem::artifact("dot_n64_f32")
            .with_deadline(now);
        assert!(past.expired(now + Duration::from_nanos(1)));
        assert!(!past.expired(now), "deadline instant itself still live");
    }

    #[test]
    fn id_parser_accepts_canonical_forms() {
        assert_eq!(parse_artifact_id("gemm_n128_t16_e1_f32"),
                   Some((128, Precision::F32)));
        assert_eq!(parse_artifact_id("gemm_n256_t32_e4_f64"),
                   Some((256, Precision::F64)));
        assert_eq!(parse_artifact_id("dot_n128_f32"),
                   Some((128, Precision::F32)));
    }

    #[test]
    fn id_parser_rejects_alpha_beta_and_junk() {
        assert_eq!(parse_artifact_id("gemm_n128_t16_e1_f32_a1.5_b0.5"),
                   None);
        assert_eq!(parse_artifact_id("mlp_b32_f32"), None);
        assert_eq!(parse_artifact_id("gemm_nX_t16_e1_f32"), None);
        assert_eq!(parse_artifact_id("gemm_n0_t16_e1_f32"), None);
        assert_eq!(parse_artifact_id(""), None);
    }

    #[test]
    fn sim_backend_predicts_and_guards_routing() {
        let park = MachinePark::default();
        let mut b = SimBackend::new(ArchId::Knl, &park);
        let p = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 1024, 64, 1);
        match b.run(&WorkItem::point(p)).unwrap() {
            Output::Sim { record, wall } => {
                assert!(record.gflops > 0.0);
                assert!(wall >= 0.0);
            }
            other => panic!("unexpected output {other:?}"),
        }
        // wrong-arch point and artifact both refused
        let wrong = TuningPoint::gpu(ArchId::K80, Precision::F32, 256, 4);
        assert!(b.run(&WorkItem::point(wrong)).is_err());
        assert!(b.run(&WorkItem::artifact("dot_n128_f32")).is_err());
    }

    #[test]
    fn synthetic_native_backend_serves_host_gemm() {
        let ids = vec!["gemm_n64_t16_e1_f32".to_string(),
                       "dot_n64_f64".to_string()];
        let mut b = NativeBackend::synthetic(&ids).unwrap();
        assert_eq!(b.artifact_ids(), {
            let mut s = ids.clone();
            s.sort();
            s
        });
        match b.run(&WorkItem::artifact(ids[0].clone())).unwrap() {
            Output::Native { artifact_id, seconds, gflops, engine,
                             kernel } => {
                assert_eq!(artifact_id, ids[0]);
                assert!(seconds > 0.0);
                assert!(gflops.unwrap() > 0.0);
                assert_eq!(engine, NativeEngine::HostGemm);
                assert!(kernel.starts_with("tuned{mc="), "{kernel}");
            }
            other => panic!("unexpected output {other:?}"),
        }
        assert!(b.run(&WorkItem::artifact("nope")).unwrap_err()
                 .to_string().contains("unknown artifact"));
    }

    #[test]
    fn threadpool_gemm_serves_and_matches_reference_oracle() {
        let ids = vec!["gemm_n96_t16_e1_f32".to_string(),
                       "dot_n64_f64".to_string()];
        let mut b = ThreadpoolGemm::synthetic(&ids, 3).unwrap();
        assert_eq!(b.threads(), 3);
        assert_eq!(b.artifact_ids(), {
            let mut s = ids.clone();
            s.sort();
            s
        });
        for id in &ids {
            // run() digest-checks every output against the sequential
            // oracle internally: an Ok IS the verification passing.
            match b.run(&WorkItem::artifact_on(
                id.clone(), NativeEngineId::Threadpool)).unwrap()
            {
                Output::Native { artifact_id, seconds, gflops,
                                 engine, kernel } => {
                    assert_eq!(&artifact_id, id);
                    assert!(seconds > 0.0);
                    assert!(gflops.unwrap() > 0.0);
                    assert_eq!(engine, NativeEngine::ThreadpoolGemm);
                    assert!(kernel.starts_with("tuned{"), "{kernel}");
                }
                other => panic!("unexpected output {other:?}"),
            }
        }
        // repeat run reuses cached inputs and still verifies
        assert!(b.run(&WorkItem::artifact_on(
            ids[0].clone(), NativeEngineId::Threadpool)).is_ok());
        // non-artifact and unknown-artifact items refused explicitly
        let p = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64, 1024, 64, 1);
        assert!(b.run(&WorkItem::point(p)).is_err());
        assert!(b.run(&WorkItem::artifact_on(
            "nope", NativeEngineId::Threadpool)).unwrap_err()
             .to_string().contains("unknown artifact"));
    }

    #[test]
    fn threadpool_parallel_digest_agrees_with_sequential_gemm() {
        // Cross-check the parallel row-block digest against a digest of
        // the plain sequential reference computed HERE (independent of
        // the backend's internal oracle bookkeeping).
        let id = "gemm_n64_t16_e1_f64".to_string();
        let mut b = ThreadpoolGemm::synthetic(
            &[id.clone()], 4).unwrap();
        assert!(b.run(&WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool)).is_ok());
        let n = 64usize;
        let a = prng::matrix_f64(prng::seed_for(&id, 0), n, n);
        let bm = prng::matrix_f64(prng::seed_for(&id, 1), n, n);
        let c = prng::matrix_f64(prng::seed_for(&id, 2), n, n);
        let full = verify::gemm_f64_rows(n, 0, n, &a, &bm, &c, 1.0, 1.0);
        let (seq_sum, seq_abs) = sum_abs_f64(&full);
        // default blocking for n=64 has mc=64; no store → fanout is
        // the pool size (4) — the oracle map's key
        let oracle = b.oracles.get(&(id.clone(), 64, 4))
            .expect("oracle recorded");
        assert!((oracle.sum - seq_sum).abs()
                    <= 1e-9 * seq_abs.max(1.0),
                "oracle {} vs sequential {}", oracle.sum, seq_sum);
    }

    #[test]
    fn oracle_computed_exactly_once_per_artifact() {
        // The sequential O(N³) oracle must never sit on the request
        // path: N requests to one artifact → exactly one oracle build.
        let ids = vec!["gemm_n80_t16_e1_f64".to_string(),
                       "dot_n48_f32".to_string()];
        let mut b = ThreadpoolGemm::synthetic(&ids, 2).unwrap();
        assert_eq!(b.oracle_builds(), 0);
        for _ in 0..5 {
            b.run(&WorkItem::artifact_on(
                ids[0].clone(), NativeEngineId::Threadpool)).unwrap();
        }
        assert_eq!(b.oracle_builds(), 1,
                   "5 requests to one artifact built the oracle once");
        b.run(&WorkItem::artifact_on(
            ids[1].clone(), NativeEngineId::Threadpool)).unwrap();
        for _ in 0..3 {
            b.run(&WorkItem::artifact_on(
                ids[1].clone(), NativeEngineId::Threadpool)).unwrap();
        }
        assert_eq!(b.oracle_builds(), 2,
                   "second artifact adds exactly one more build");
    }

    fn model_backend(threads: usize) -> ThreadpoolGemm {
        let text = crate::model::demo_manifest_text();
        let m = Manifest::parse(&text, std::path::Path::new(".")).unwrap();
        ThreadpoolGemm::from_manifest(&m, threads)
    }

    fn run_node(b: &mut ThreadpoolGemm, id: &str)
                -> Result<Output, BackendFailure> {
        b.run(&WorkItem::artifact_on(id, NativeEngineId::Threadpool))
    }

    #[test]
    fn model_fused_nodes_serve_with_epilogue_labels() {
        let mut b = model_backend(3);
        match run_node(&mut b, "mlp_b64_f32#L0").unwrap() {
            Output::Native { artifact_id, seconds, gflops, engine,
                             kernel } => {
                assert_eq!(artifact_id, "mlp_b64_f32#L0");
                assert!(seconds > 0.0);
                assert!(gflops.unwrap() > 0.0);
                assert_eq!(engine, NativeEngine::ThreadpoolGemm);
                // fused tier: tuned kernel + the fused epilogue, both
                // visible in the label
                assert!(kernel.starts_with("tuned{"), "{kernel}");
                assert!(kernel.ends_with("+bias+tanh"), "{kernel}");
            }
            other => panic!("unexpected output {other:?}"),
        }
        match run_node(&mut b, "mlp_b64_f32#L1").unwrap() {
            Output::Native { kernel, .. } => {
                assert!(kernel.ends_with("+bias"), "{kernel}");
            }
            other => panic!("unexpected output {other:?}"),
        }
        // One strict build per layer, never one per request.
        assert_eq!(b.oracle_builds(), 2);
        for _ in 0..3 {
            run_node(&mut b, "mlp_b64_f32#L0").unwrap();
            run_node(&mut b, "mlp_b64_f32#L1").unwrap();
        }
        assert_eq!(b.oracle_builds(), 2,
                   "warm model requests never rebuild the oracle");
    }

    #[test]
    fn model_strict_and_unfused_nodes_serve() {
        let mut b = model_backend(2);
        // Strict tier: sequential reference, bit-identity with the
        // oracle (Ok IS the verification).
        match run_node(&mut b, "mlp_b64_f32#L0+strict").unwrap() {
            Output::Native { kernel, .. } => {
                assert_eq!(kernel, "strict+bias+tanh");
            }
            other => panic!("unexpected output {other:?}"),
        }
        match run_node(&mut b, "mlp_b64_f32#L1+strict").unwrap() {
            Output::Native { kernel, .. } => {
                assert_eq!(kernel, "strict+bias");
            }
            other => panic!("unexpected output {other:?}"),
        }
        // Unfused tier: bias-only GEMM stage + activation pass.
        match run_node(&mut b, "mlp_b64_f32#L0!gemm").unwrap() {
            Output::Native { kernel, .. } => {
                assert!(kernel.ends_with("+bias"), "{kernel}");
            }
            other => panic!("unexpected output {other:?}"),
        }
        match run_node(&mut b, "mlp_b64_f32#L0!act").unwrap() {
            Output::Native { kernel, gflops, .. } => {
                assert_eq!(kernel, "det-tanh");
                assert!(gflops.is_none());
            }
            other => panic!("unexpected output {other:?}"),
        }
        // L1 never activates: no `!act` node exists for it.
        assert!(run_node(&mut b, "mlp_b64_f32#L1!act").unwrap_err()
                .to_string().contains("unknown artifact"));
    }

    #[test]
    fn model_nodes_absent_from_synthetic_backends() {
        let mut b = ThreadpoolGemm::synthetic(
            &["gemm_n64_t16_e1_f32".to_string()], 2).unwrap();
        assert!(run_node(&mut b, "mlp_b64_f32#L0").unwrap_err()
                .to_string().contains("unknown artifact"),
                "model nodes need a manifest, not synthetic ids");
    }

    #[test]
    fn model_chaos_corruption_trips_the_real_digest_check() {
        let plan = Arc::new(
            FaultPlan::new(7).with_rate(FaultSite::CorruptOutput, 1.0));
        let mut b = model_backend(2).with_fault(Some(plan));
        match run_node(&mut b, "mlp_b64_f32#L0").unwrap_err() {
            BackendFailure::Corrupted { artifact, detail } => {
                assert_eq!(artifact, "mlp_b64_f32#L0");
                assert!(detail.contains("digest mismatch"), "{detail}");
            }
            other => panic!("expected Corrupted, got {other:?}"),
        }
    }

    #[test]
    fn threadpool_chunks_preserve_fanout_for_small_n() {
        let b = ThreadpoolGemm::synthetic(
            &["dot_n64_f32".to_string()], 4).unwrap();
        // per-thread share (64/8 = 8 rows) is below one mc=64 panel:
        // chunks must stay small instead of collapsing to one block
        let chunks = b.chunks(64, 64, b.threads());
        assert!(chunks.len() >= 4, "{chunks:?}");
        assert_eq!(chunks.first().unwrap().0, 0);
        assert_eq!(chunks.last().unwrap().1, 64);
        for w in chunks.windows(2) {
            assert_eq!(w[0].1, w[1].0, "contiguous cover");
        }
        // large N: chunk boundaries land on whole mc panels
        let big = b.chunks(512, 64, b.threads());
        assert!(big.len() >= 4, "{big:?}");
        for (r0, _) in &big {
            assert_eq!(r0 % 64, 0);
        }
        assert_eq!(big.last().unwrap().1, 512);
    }

    #[test]
    fn store_thread_count_narrows_the_fanout() {
        use crate::autotune::{TuneEntry, TuningStore};
        let id = "gemm_n128_t16_e1_f64".to_string();
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        let fp = store.lock().unwrap().fingerprint().to_string();
        // a measured winner that says 1 worker beats the full pool
        store.lock().unwrap().commit_entry(TuneEntry {
            fingerprint: fp,
            dtype: Precision::F64,
            bucket: 128,
            params: KernelParams::new(64, 64, 64, 4, 4).unwrap(),
            threads: Some(1),
            gflops: 1.0,
            samples: 1,
        }).unwrap();
        let mut b = ThreadpoolGemm::synthetic(&[id.clone()], 4)
            .unwrap()
            .with_store(Some(Arc::clone(&store)));
        // effective fan-out: stored 1, clamped to the pool
        assert_eq!(b.fanout(Some(1)), 1);
        assert_eq!(b.fanout(Some(99)), 4, "never exceeds the pool");
        assert_eq!(b.fanout(None), 4);
        // 1-worker chunking: ~2 chunks, not 8
        assert!(b.chunks(128, 64, 1).len() <= 2);
        // the run selects the stored fan-out, keys the oracle by it,
        // and still digest-verifies (Ok IS the verification)
        match b.run(&WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool)).unwrap()
        {
            Output::Native { kernel, .. } => {
                assert!(kernel.ends_with("@store"), "{kernel}");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(b.oracles.contains_key(&(id, 64, 1)),
                "oracle keyed by the narrowed fan-out");
    }

    #[test]
    fn threadpool_serves_non_divisible_n() {
        // Edge-tile path end to end: N=100 is divisible by neither the
        // default mc=64 panel height nor the 4x4 register tile width,
        // and the digest check against the naive oracle must still pass.
        let id = "gemm_n100_t16_e1_f64".to_string();
        let mut b = ThreadpoolGemm::synthetic(&[id.clone()], 3).unwrap();
        let out = b.run(&WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool)).unwrap();
        match out {
            Output::Native { engine, kernel, .. } => {
                assert_eq!(engine, NativeEngine::ThreadpoolGemm);
                assert!(kernel.contains("mc=64"), "{kernel}");
            }
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn store_params_select_and_oracle_follows_the_new_blocking() {
        use crate::autotune::TuningStore;
        let id = "gemm_n64_t16_e1_f64".to_string();
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        let mut b = ThreadpoolGemm::synthetic(&[id.clone()], 3)
            .unwrap()
            .with_store(Some(Arc::clone(&store)));
        // cold store: defaults serve, no @store suffix
        match b.run(&WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool)).unwrap()
        {
            Output::Native { kernel, .. } => {
                assert!(kernel.starts_with("tuned{"), "{kernel}");
                assert!(!kernel.ends_with("@store"), "{kernel}");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.oracle_builds(), 1);
        // commit a DIFFERENT blocking (mc=32): selection must pick it
        // up on the very next request, rebuild the oracle once under
        // the new chunking, and the digest check must still pass
        // (Ok IS the verification).
        store.lock().unwrap()
            .commit(Precision::F64, 64,
                    KernelParams::new(32, 64, 32, 4, 4).unwrap(),
                    1.0, 1)
            .unwrap();
        match b.run(&WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool)).unwrap()
        {
            Output::Native { kernel, .. } => {
                assert!(kernel.contains("mc=32"), "{kernel}");
                assert!(kernel.ends_with("@store"), "{kernel}");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(b.oracle_builds(), 2,
                   "one more oracle for the new blocking");
        // repeat: no further oracle builds
        b.run(&WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool)).unwrap();
        assert_eq!(b.oracle_builds(), 2);
    }

    #[test]
    fn native_backend_host_fallback_consults_store() {
        use crate::autotune::TuningStore;
        let id = "gemm_n64_t16_e1_f32".to_string();
        let store = Arc::new(Mutex::new(TuningStore::in_memory()));
        store.lock().unwrap()
            .commit(Precision::F32, 64,
                    KernelParams::new(16, 16, 16, 2, 2).unwrap(),
                    1.0, 1)
            .unwrap();
        let mut b = NativeBackend::synthetic(&[id.clone()]).unwrap()
            .with_store(Some(store));
        match b.run(&WorkItem::artifact(id)).unwrap() {
            Output::Native { kernel, engine, .. } => {
                assert_eq!(engine, NativeEngine::HostGemm);
                assert!(kernel.ends_with("@store"), "{kernel}");
                assert!(kernel.contains("mc=16"), "{kernel}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn explore_items_route_to_the_tuner_shard() {
        let w = WorkItem::explore(Precision::F64, 128);
        assert_eq!(w.shard_key(), ShardKey::Tuner);
        assert_eq!(ShardKey::Tuner.label(), "tune:explore");
        assert_eq!(w.cache_key(), "explore:f64:128");
        assert_ne!(w.cache_key(),
                   WorkItem::explore(Precision::F32, 128).cache_key());
        // compute backends refuse exploration payloads explicitly
        let park = MachinePark::default();
        let mut sim = SimBackend::new(ArchId::Knl, &park);
        assert!(sim.run(&w).is_err());
        let mut tp = ThreadpoolGemm::synthetic(
            &["dot_n64_f32".to_string()], 1).unwrap();
        assert!(tp.run(&w).is_err());
        let mut nb = NativeBackend::synthetic(
            &["dot_n64_f32".to_string()]).unwrap();
        assert!(nb.run(&w).is_err());
    }

    #[test]
    fn injected_corruption_trips_the_real_oracle() {
        let id = "gemm_n48_t16_e1_f64".to_string();
        let plan = Arc::new(FaultPlan::new(7)
            .with_rate(FaultSite::CorruptOutput, 1.0));
        let mut b = ThreadpoolGemm::synthetic(&[id.clone()], 2)
            .unwrap()
            .with_fault(Some(plan));
        match b.run(&WorkItem::artifact_on(
            id.clone(), NativeEngineId::Threadpool))
        {
            Err(BackendFailure::Corrupted { artifact, detail }) => {
                assert_eq!(artifact, id);
                assert!(detail.contains("digest mismatch"), "{detail}");
            }
            other => panic!("expected corruption, got {other:?}"),
        }
        // without the fault plan the same artifact serves cleanly —
        // the corruption is injected, not organic
        let mut clean =
            ThreadpoolGemm::synthetic(&[id.clone()], 2).unwrap();
        assert!(clean.run(&WorkItem::artifact_on(
            id, NativeEngineId::Threadpool)).is_ok());
    }

    #[test]
    fn backend_failure_display_and_from() {
        let e: BackendFailure = "boom".into();
        assert_eq!(e.to_string(), "boom");
        let c = BackendFailure::Corrupted {
            artifact: "a1".to_string(),
            detail: "sum off".to_string(),
        };
        assert_eq!(c.to_string(), "corrupted output for a1: sum off");
    }

    #[test]
    fn threadpool_gemm_rejects_unparseable_ids_and_non_host_artifacts() {
        assert!(ThreadpoolGemm::synthetic(
            &["mlp_b32_f32".to_string()], 2).is_err());
        assert!(ThreadpoolGemm::synthetic(
            &["gemm_n2048_t16_e1_f32".to_string()], 2).is_err());
    }

    #[test]
    fn synthetic_rejects_unparseable_and_oversized() {
        assert!(NativeBackend::synthetic(
            &["mlp_b32_f32".to_string()]).is_err());
        assert!(NativeBackend::synthetic(
            &["gemm_n2048_t16_e1_f32".to_string()]).is_err());
    }
}
