//! The unified serve layer — ONE admission-controlled front queue, ONE
//! dispatcher, per-backend **shards**, cross-request **batching**, an
//! LRU **result cache** and unified **metrics**, shared by everything
//! that executes work in this repo.
//!
//! Before this module existed the repo had two disjoint concurrency
//! stacks: `coordinator::Scheduler` (sweep jobs over simulated
//! machines) and `runtime::GemmService` (PJRT artifact serving), each
//! with its own queue, worker loop and counters. The paper's own thesis
//! — one implementation, tuned per backend — applies to the serving
//! plane too, so both are now thin shims over this layer.
//!
//! # Architecture
//!
//! ```text
//!  clients ──submit──▶ front BoundedQueue (admission control)
//!                          │ dispatcher thread (+ per-shard quotas)
//!            ┌─────────────┼──────────────┬──────────────┐
//!            ▼             ▼              ▼              ▼
//!      shard sim:knl  shard sim:…   shard native:pjrt  shard
//!      (N threads)    (N threads)   (1 thread — the    native:threadpool
//!            │             │         PJRT client is    (1 worker over an
//!            ▼             ▼         Rc-based)          M-thread pool)
//!       pop_batch → shed expired → group by work key → LRU cache
//!                          │                              → Backend::run
//!                          └──▶ reply channels + ServeMetrics
//! ```
//!
//! * **Admission**: `submit` blocks while the front queue is full
//!   (backpressure) and fails *explicitly* with [`ServeError::Closed`]
//!   after shutdown — a request is never silently dropped.
//! * **Overload control**: with a [`ShedPolicy`] configured, a shard
//!   whose outstanding line reached `ServeConfig::shard_quota` sheds
//!   new arrivals with [`ServeError::Overloaded`] at routing time, and
//!   (policy `ShedExpired`) items whose [`WorkItem`] deadline passed
//!   are shed at dequeue — overload is never a silent drop NOR an
//!   unbounded block.
//! * **Shards**: created lazily by the dispatcher, one per simulated
//!   [`ArchId`](crate::arch::ArchId) plus one per **named** native
//!   engine ([`NativeEngineId`]): `native:pjrt` (single-owner PJRT,
//!   host reference-GEMM fallback) and `native:threadpool` (row-blocked
//!   host GEMM over [`crate::util::threadpool::ThreadPool`],
//!   oracle-checked per run).
//! * **Batching**: shard workers drain up to `max_batch` requests in one
//!   `pop_batch`, group them by work key, and serve each group with one
//!   backend execution.
//! * **Caching**: per-shard LRU keyed by the canonical work-item key;
//!   disabled (capacity 0) for measurement-oriented callers.
//! * **Shutdown**: `close` stops admission; queued work is drained,
//!   executed and replied to before workers exit. `cancel` short-cuts
//!   execution but still replies ([`ServeError::Cancelled`]).

pub mod backend;
pub mod cache;
pub mod loadgen;
pub mod metrics;

use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::queue::BoundedQueue;
use crate::runtime::artifact::Manifest;

pub use backend::{Backend, BackendFactory, MachinePark, NativeBackend,
                  NativeEngine, NativeEngineId, Output, ShardKey,
                  SimBackend, ThreadpoolGemm, WorkItem, WorkPayload};
pub use cache::LruCache;
pub use metrics::ServeMetrics;

/// Why a request did not produce an output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The serve layer is shut down; the request was rejected at
    /// admission (explicitly — never a dangling channel).
    Closed,
    /// `cancel()` was called before this request executed.
    Cancelled,
    /// Overload control shed this request — the shard's admission
    /// quota was exceeded, or the item's deadline expired before
    /// execution started. Always an explicit reply: overload is never
    /// a silent drop, and (with a shed policy configured) never an
    /// unbounded block either.
    Overloaded {
        /// Label of the shard that was overloaded (e.g. `native:pjrt`).
        shard: String,
        /// Outstanding depth observed at the shed decision.
        depth: usize,
        /// The configured per-shard quota (0 when shedding was
        /// triggered by deadline expiry with no quota set).
        quota: usize,
    },
    /// The backend refused or failed the request.
    Backend(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Closed => {
                write!(f, "serve layer closed: request rejected")
            }
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::Overloaded { shard, depth, quota } => {
                write!(f, "shard {shard} overloaded (depth {depth}, \
                           quota {quota}): request shed")
            }
            ServeError::Backend(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What the serve layer does when a shard is past its admission quota
/// or a request's deadline has expired. Orthogonal to every other knob:
/// the default (`None`) is PR-1 behavior — pure backpressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Never shed: requests queue (bounded by queue capacities and the
    /// dispatcher's overflow buffers) and block producers when full.
    None,
    /// Reject with [`ServeError::Overloaded`] at routing time when a
    /// shard's outstanding depth (its queue + its overflow line) has
    /// reached `ServeConfig::shard_quota`.
    RejectOverQuota,
    /// [`ShedPolicy::RejectOverQuota`] *plus* shed items whose deadline
    /// has already expired when a shard worker dequeues them (the work
    /// would be wasted — its result can no longer arrive in time).
    ShedExpired,
}

impl ShedPolicy {
    pub fn rejects_over_quota(&self) -> bool {
        matches!(self, ShedPolicy::RejectOverQuota
                     | ShedPolicy::ShedExpired)
    }

    pub fn sheds_expired(&self) -> bool {
        matches!(self, ShedPolicy::ShedExpired)
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(ShedPolicy::None),
            "reject" => Some(ShedPolicy::RejectOverQuota),
            "expire" => Some(ShedPolicy::ShedExpired),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShedPolicy::None => "none",
            ShedPolicy::RejectOverQuota => "reject",
            ShedPolicy::ShedExpired => "expire",
        }
    }
}

/// A served request's full story.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// Label of the shard that served it (e.g. `sim:knl`,
    /// `native:pjrt`, `native:threadpool`).
    pub shard: String,
    pub output: Output,
    /// Size of the coalesced group this request was served in.
    pub batch_size: usize,
    /// Wait from submission to the start of execution, seconds.
    pub queue_seconds: f64,
    /// Whether the result came from the shard's LRU cache.
    pub cache_hit: bool,
    /// Worker index within the shard.
    pub worker: usize,
}

pub type ReplyRx = Receiver<Result<ServeReply, ServeError>>;

/// Reply continuation, invoked exactly once per request — by a shard
/// worker, or by the admission path on rejection. Adapters (the
/// Scheduler/GemmService shims) use this to translate the reply type
/// without forwarder threads.
pub type ReplyFn = Box<dyn FnOnce(Result<ServeReply, ServeError>) + Send>;

struct ServeRequest {
    item: WorkItem,
    reply: ReplyFn,
    enqueued: Instant,
}

/// Where the native shard gets its artifacts.
#[derive(Debug, Clone)]
pub enum NativeConfig {
    /// Load `manifest.json` from this directory (PJRT path, with host
    /// reference-GEMM fallback when device execution is unavailable).
    Artifacts(PathBuf),
    /// Manifest-less synthetic catalog from parseable artifact ids
    /// (host reference GEMM only) — for load tests without artifacts.
    Synthetic(Vec<String>),
}

/// Serve-layer tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Front (admission) queue capacity.
    pub front_cap: usize,
    /// Per-shard queue capacity.
    pub shard_cap: usize,
    /// Maximum requests coalesced per `pop_batch`.
    pub max_batch: usize,
    /// LRU result-cache entries per shard; 0 disables caching
    /// (measurement-oriented callers must re-execute every request).
    pub cache_cap: usize,
    /// Worker threads per simulated shard (each native shard has
    /// exactly one shard worker — the PJRT client is single-owner, and
    /// the threadpool shard parallelizes *inside* its backend).
    pub sim_threads: usize,
    pub native: Option<NativeConfig>,
    /// Threads inside the `native:threadpool` backend's worker pool
    /// (0 = host-sized).
    pub native_threads: usize,
    /// Overload behavior; see [`ShedPolicy`].
    pub shed: ShedPolicy,
    /// Per-shard admission quota: a shard with this many outstanding
    /// requests (its queue plus its overflow line) sheds new arrivals
    /// when the policy rejects over quota. `None` = unlimited.
    pub shard_quota: Option<usize>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { front_cap: 64, shard_cap: 64, max_batch: 8, cache_cap: 0,
               sim_threads: 1, native: None, native_threads: 4,
               shed: ShedPolicy::None, shard_quota: None }
    }
}

/// Read-only after start; shared via `Arc` so the two named native
/// shards draw from one copy instead of cloning the whole manifest
/// into each factory.
enum NativeSource {
    Manifest(Manifest),
    Synthetic(Vec<String>),
}

struct ShardHandle {
    queue: Arc<BoundedQueue<ServeRequest>>,
    workers: Vec<JoinHandle<()>>,
}

/// Live registry of shard queues (label → queue), shared between the
/// dispatcher (which registers shards as it spawns them) and
/// [`Serve::summary`]/[`Serve::shard_depths`] — so a *mid-run* summary
/// sees real per-shard depth high-water marks instead of zeros that
/// only get folded in at shutdown.
type ShardRegistry = Mutex<Vec<(String, Arc<BoundedQueue<ServeRequest>>)>>;

/// Handle to a running serve layer.
pub struct Serve {
    front: Arc<BoundedQueue<ServeRequest>>,
    dispatcher: Option<JoinHandle<()>>,
    pub metrics: Arc<ServeMetrics>,
    cancel: Arc<AtomicBool>,
    park: Arc<MachinePark>,
    shard_queues: Arc<ShardRegistry>,
}

impl Serve {
    /// Start the layer. The native manifest (when configured) is loaded
    /// eagerly so configuration errors surface here, not on the first
    /// artifact request; shard threads spawn lazily on first use.
    pub fn start(cfg: ServeConfig) -> crate::Result<Serve> {
        let native_src = match &cfg.native {
            None => None,
            Some(NativeConfig::Artifacts(dir)) => {
                Some(Arc::new(NativeSource::Manifest(
                    Manifest::load(dir)?)))
            }
            Some(NativeConfig::Synthetic(ids)) => {
                // validate ids eagerly
                for id in ids {
                    if backend::parse_artifact_id(id).is_none() {
                        anyhow::bail!(
                            "unsupported synthetic artifact id {id:?}");
                    }
                }
                Some(Arc::new(NativeSource::Synthetic(ids.clone())))
            }
        };
        let front: Arc<BoundedQueue<ServeRequest>> =
            Arc::new(BoundedQueue::new(cfg.front_cap.max(1)));
        let metrics = Arc::new(ServeMetrics::new());
        let cancel = Arc::new(AtomicBool::new(false));
        let park = Arc::new(MachinePark::default());
        let shard_queues: Arc<ShardRegistry> =
            Arc::new(Mutex::new(Vec::new()));
        let dispatcher = {
            let front = Arc::clone(&front);
            let metrics = Arc::clone(&metrics);
            let cancel = Arc::clone(&cancel);
            let park = Arc::clone(&park);
            let registry = Arc::clone(&shard_queues);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("serve-dispatch".into())
                .spawn(move || {
                    dispatch_loop(front, cfg, native_src, park, metrics,
                                  cancel, registry)
                })
                .expect("spawn serve dispatcher")
        };
        Ok(Serve { front, dispatcher: Some(dispatcher), metrics, cancel,
                   park, shard_queues })
    }

    /// Submit a work item. Blocks while the front queue is full
    /// (admission control). The returned channel ALWAYS yields exactly
    /// one explicit result — after shutdown that result is
    /// `Err(ServeError::Closed)`, never a dangling disconnect.
    pub fn submit(&self, item: WorkItem) -> ReplyRx {
        let (tx, rx) = channel();
        self.submit_with(item, Box::new(move |r| {
            let _ = tx.send(r);
        }));
        rx
    }

    /// Submit with a reply continuation instead of a channel. The
    /// continuation runs exactly once — with `Err(ServeError::Closed)`
    /// synchronously when admission is already shut down.
    pub fn submit_with(&self, item: WorkItem, reply: ReplyFn) {
        self.metrics.request_submitted();
        // Depth high-water comes from the queue's own max_depth (one
        // lock inside push), not a separate len() read per request.
        let req = ServeRequest { item, reply,
                                 enqueued: Instant::now() };
        if let Err(req) = self.front.push_or_return(req) {
            self.metrics.request_failed();
            (req.reply)(Err(ServeError::Closed));
        }
    }

    /// Like [`Serve::submit`] but reports shutdown on the call itself.
    pub fn try_submit(&self, item: WorkItem)
                      -> Result<ReplyRx, ServeError> {
        if self.front.is_closed() {
            self.metrics.request_submitted();
            self.metrics.request_failed();
            return Err(ServeError::Closed);
        }
        Ok(self.submit(item))
    }

    /// Submit and wait.
    pub fn call(&self, item: WorkItem) -> Result<ServeReply, ServeError> {
        // recv error cannot happen (every request gets an explicit
        // reply); map it to Closed defensively rather than panicking.
        self.submit(item).recv().unwrap_or(Err(ServeError::Closed))
    }

    /// Request cancellation: queued work is drained and replied to with
    /// [`ServeError::Cancelled`] instead of executing.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Stop admission (idempotent). Queued requests still complete;
    /// subsequent `submit`s get an explicit `Closed` error.
    pub fn close(&self) {
        self.front.close();
    }

    /// Current front-queue depth (for admission metrics).
    pub fn front_depth(&self) -> usize {
        self.front.len()
    }

    /// High-water mark of the front queue since start (tracked inside
    /// the queue itself — no per-request metric calls on the hot path).
    pub fn front_depth_high_water(&self) -> usize {
        self.front.max_depth()
    }

    /// Unified metrics summary with the queue-depth high-water marks
    /// folded in **at observation time** (they live in the queues until
    /// read) — a mid-run summary reports real shard depths, not the
    /// zeros a shutdown-only fold would show.
    pub fn summary(&self) -> String {
        self.metrics.observe_front_depth(self.front.max_depth());
        for (_, q) in self.shard_queues.lock()
            .expect("shard registry poisoned").iter()
        {
            self.metrics.observe_shard_depth(q.max_depth());
        }
        self.metrics.summary()
    }

    /// Live per-shard queue visibility: `(label, current depth,
    /// high-water depth)` for every shard spawned so far.
    pub fn shard_depths(&self) -> Vec<(String, usize, usize)> {
        self.shard_queues.lock().expect("shard registry poisoned")
            .iter()
            .map(|(label, q)| (label.clone(), q.len(), q.max_depth()))
            .collect()
    }

    /// The shared machine-model registry (pre-warm, inspection).
    pub fn park(&self) -> &Arc<MachinePark> {
        &self.park
    }

    /// Graceful shutdown: close admission, drain, join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.front.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Serve {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn dispatch_loop(front: Arc<BoundedQueue<ServeRequest>>, cfg: ServeConfig,
                 native_src: Option<Arc<NativeSource>>,
                 park: Arc<MachinePark>, metrics: Arc<ServeMetrics>,
                 cancel: Arc<AtomicBool>,
                 registry: Arc<ShardRegistry>) {
    use std::collections::VecDeque;
    use std::time::Duration;

    use crate::coordinator::queue::PushRefusal;

    let mut shards: HashMap<ShardKey, ShardHandle> = HashMap::new();
    // Per-shard overflow buffers: when one shard's queue is full, its
    // requests wait HERE instead of blocking the dispatcher — a slow
    // native shard must not head-of-line-block sim traffic sitting
    // behind it in the single front queue. Bounded: past the limit the
    // dispatcher blocks on the saturated shard only (memory stays
    // bounded; other shards were already routed).
    let mut overflow: HashMap<ShardKey, VecDeque<ServeRequest>> =
        HashMap::new();
    let mut overflow_len = 0usize;
    let overflow_limit = cfg.front_cap.max(16) * 4;
    // Effective per-shard admission quota, fixed for this dispatcher's
    // lifetime (usize::MAX = no shedding).
    let quota = match cfg.shard_quota {
        Some(q) if cfg.shed.rejects_over_quota() => q,
        _ => usize::MAX,
    };
    let mut front_open = true;

    while front_open || overflow_len > 0 {
        // 1. Flush overflows opportunistically (FIFO per shard).
        for (key, buf) in overflow.iter_mut() {
            let handle = shards.get(key).expect("overflow implies shard");
            while let Some(req) = buf.pop_front() {
                match handle.queue.try_push(req) {
                    Ok(()) => overflow_len -= 1,
                    Err(req) => {
                        buf.push_front(req);
                        break;
                    }
                }
            }
        }
        if !front_open {
            // Nothing new can arrive: drain remaining overflow with
            // blocking pushes (shard queues are still open — they close
            // below, after this loop).
            for (key, buf) in overflow.iter_mut() {
                let handle =
                    shards.get(key).expect("overflow implies shard");
                for req in buf.drain(..) {
                    overflow_len -= 1;
                    if let Err(req) = handle.queue.push_or_return(req) {
                        metrics.request_failed();
                        (req.reply)(Err(ServeError::Closed));
                    }
                }
            }
            break;
        }

        // 2. Take the next burst from the front queue. With overflow
        // pending we only poll briefly so stalled shards keep getting
        // flush attempts; otherwise we block until work or close.
        let burst = if overflow_len == 0 {
            let b = front.pop_batch(32);
            if b.is_empty() {
                front_open = false;
                continue;
            }
            b
        } else {
            match front.pop_batch_timeout(32, Duration::from_millis(1)) {
                Ok(b) => b, // possibly empty: timeout → retry flush
                Err(_closed) => {
                    front_open = false;
                    continue;
                }
            }
        };

        // 3. Route the burst.
        for req in burst {
            let key = req.item.shard_key();
            if !shards.contains_key(&key) {
                match spawn_shard(key, &cfg, &native_src, &park,
                                  &metrics, &cancel) {
                    Ok(handle) => {
                        registry.lock().expect("shard registry poisoned")
                            .push((key.label(),
                                   Arc::clone(&handle.queue)));
                        shards.insert(key, handle);
                    }
                    Err(e) => {
                        metrics.request_failed();
                        (req.reply)(Err(ServeError::Backend(
                            format!("{}: {e}", key.label()))));
                        continue;
                    }
                }
            }
            let handle = shards.get(&key).expect("just ensured");
            let buf = overflow.entry(key).or_default();
            // Admission quota: the shard's outstanding line is its
            // queue PLUS its overflow buffer; with a rejecting policy
            // anything past the quota is shed HERE, explicitly, instead
            // of growing the line without bound. When the overflow
            // buffer is empty the queue enforces the quota itself
            // (try_push_quota); otherwise the combined queue+overflow
            // depth is checked manually below before joining the line.
            if buf.is_empty() {
                match handle.queue.try_push_quota(req, quota) {
                    Ok(()) => continue,
                    Err(PushRefusal::OverQuota(req, depth)) => {
                        metrics.request_shed();
                        (req.reply)(Err(ServeError::Overloaded {
                            shard: key.label(),
                            depth,
                            quota,
                        }));
                        continue;
                    }
                    Err(PushRefusal::Closed(req)) => {
                        // shard queues only close during shutdown,
                        // after this loop — defensive, never silent
                        metrics.request_failed();
                        (req.reply)(Err(ServeError::Closed));
                        continue;
                    }
                    Err(PushRefusal::Full(req)) => {
                        buf.push_back(req);
                        overflow_len += 1;
                    }
                }
            } else {
                let outstanding = handle.queue.len() + buf.len();
                if outstanding >= quota {
                    metrics.request_shed();
                    (req.reply)(Err(ServeError::Overloaded {
                        shard: key.label(),
                        depth: outstanding,
                        quota,
                    }));
                    continue;
                }
                // keep FIFO: never jump the shard's waiting line
                buf.push_back(req);
                overflow_len += 1;
            }
            // Memory bound: block on the saturated shard only.
            while overflow_len >= overflow_limit {
                let Some(req) = buf.pop_front() else { break };
                overflow_len -= 1;
                if let Err(req) = handle.queue.push_or_return(req) {
                    metrics.request_failed();
                    (req.reply)(Err(ServeError::Closed));
                }
            }
        }
    }

    for handle in shards.values() {
        handle.queue.close();
    }
    // Fold the per-queue high-water marks into the shared metrics now
    // that routing is over (cheaper than per-request observation).
    metrics.observe_front_depth(front.max_depth());
    for (_, handle) in shards.drain() {
        metrics.observe_shard_depth(handle.queue.max_depth());
        for w in handle.workers {
            let _ = w.join();
        }
    }
}

fn spawn_shard(key: ShardKey, cfg: &ServeConfig,
               native_src: &Option<Arc<NativeSource>>,
               park: &Arc<MachinePark>, metrics: &Arc<ServeMetrics>,
               cancel: &Arc<AtomicBool>)
               -> Result<ShardHandle, String> {
    let queue: Arc<BoundedQueue<ServeRequest>> =
        Arc::new(BoundedQueue::new(cfg.shard_cap.max(1)));
    let cache: Arc<Mutex<LruCache<Output>>> =
        Arc::new(Mutex::new(LruCache::new(cfg.cache_cap)));
    let threads = match key {
        ShardKey::Sim(_) => cfg.sim_threads.max(1),
        // Single shard worker per native engine: the PJRT client is
        // Rc-based (single-owner), and the threadpool backend
        // parallelizes inside itself.
        ShardKey::Native(_) => 1,
    };
    let mut factories: Vec<BackendFactory> = Vec::new();
    match key {
        ShardKey::Sim(arch) => {
            for _ in 0..threads {
                let park = Arc::clone(park);
                factories.push(Box::new(move || {
                    Ok(Box::new(SimBackend::new(arch, &park))
                       as Box<dyn Backend>)
                }));
            }
        }
        ShardKey::Native(engine) => {
            // Both named native shards draw from the SAME shared
            // artifact source (Arc — `native:pjrt` and
            // `native:threadpool` read one copy of the manifest).
            let src = Arc::clone(native_src.as_ref().ok_or_else(|| {
                "no native backend configured (start the serve layer \
                 with ServeConfig::native set)".to_string()
            })?);
            let native_threads = cfg.native_threads;
            factories.push(Box::new(move || {
                let b: Box<dyn Backend> = match (engine, &*src) {
                    (NativeEngineId::Pjrt,
                     NativeSource::Manifest(m)) => {
                        // the PJRT backend owns its manifest (it keeps
                        // loading kernels from it) — one clone here
                        Box::new(NativeBackend::from_manifest(m.clone()))
                    }
                    (NativeEngineId::Pjrt,
                     NativeSource::Synthetic(ids)) => {
                        Box::new(NativeBackend::synthetic(ids)?)
                    }
                    (NativeEngineId::Threadpool,
                     NativeSource::Manifest(m)) => {
                        Box::new(ThreadpoolGemm::from_manifest(
                            m, native_threads))
                    }
                    (NativeEngineId::Threadpool,
                     NativeSource::Synthetic(ids)) => {
                        Box::new(ThreadpoolGemm::synthetic(
                            ids, native_threads)?)
                    }
                };
                Ok(b)
            }));
        }
    }
    let shed = cfg.shed;
    let quota = cfg.shard_quota.unwrap_or(0);
    let workers = factories
        .into_iter()
        .enumerate()
        .map(|(widx, factory)| {
            let queue = Arc::clone(&queue);
            let cache = Arc::clone(&cache);
            let metrics = Arc::clone(metrics);
            let cancel = Arc::clone(cancel);
            let label = key.label();
            let max_batch = cfg.max_batch.max(1);
            std::thread::Builder::new()
                .name(format!("serve-{}-{widx}", label.replace(':', "-")))
                .spawn(move || {
                    shard_loop(queue, factory, cache, metrics, cancel,
                               max_batch, widx, label, shed, quota)
                })
                .expect("spawn shard worker")
        })
        .collect();
    Ok(ShardHandle { queue, workers })
}

/// Fold one *executed* native output into the per-shard compute
/// aggregate (cache hits never reach this — they do no compute).
fn observe_native_compute(metrics: &ServeMetrics, shard: &str,
                          output: &Output) {
    if let Output::Native { seconds, gflops: Some(g), .. } = output {
        metrics.observe_compute(shard, *seconds, *g);
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_loop(queue: Arc<BoundedQueue<ServeRequest>>,
              factory: BackendFactory,
              cache: Arc<Mutex<LruCache<Output>>>,
              metrics: Arc<ServeMetrics>, cancel: Arc<AtomicBool>,
              max_batch: usize, worker: usize, label: String,
              shed: ShedPolicy, quota: usize) {
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            // Init failed: every request — queued now or later — gets an
            // explicit error until the queue closes.
            loop {
                let batch = queue.pop_batch(max_batch);
                if batch.is_empty() {
                    return;
                }
                for req in batch {
                    metrics.request_failed();
                    (req.reply)(Err(ServeError::Backend(
                        format!("{label}: backend init failed: {e}"))));
                }
            }
        }
    };
    loop {
        let mut batch = queue.pop_batch(max_batch);
        if batch.is_empty() {
            return; // closed and drained
        }
        // Deadline shedding at dequeue: executing an already-expired
        // request wastes backend time that live requests behind it
        // need — shed it with an explicit Overloaded reply instead.
        if shed.sheds_expired() {
            let now = Instant::now();
            let depth = queue.len();
            let mut live = Vec::with_capacity(batch.len());
            for req in batch {
                if req.item.expired(now) {
                    metrics.request_shed();
                    (req.reply)(Err(ServeError::Overloaded {
                        shard: label.clone(),
                        depth,
                        quota,
                    }));
                } else {
                    live.push(req);
                }
            }
            batch = live;
            if batch.is_empty() {
                continue;
            }
        }
        // Continuous batching: group the drained requests by work key
        // (first-appearance order) and serve each group with ONE
        // backend execution.
        let mut order: Vec<String> = Vec::new();
        let mut groups: HashMap<String, Vec<ServeRequest>> =
            HashMap::new();
        for req in batch {
            let key = req.item.cache_key();
            groups.entry(key.clone()).or_insert_with(|| {
                order.push(key);
                Vec::new()
            }).push(req);
        }
        for key in order {
            let group = groups.remove(&key).expect("grouped above");
            let batch_size = group.len();
            metrics.observe_batch(batch_size);

            if cancel.load(Ordering::SeqCst) {
                for req in group {
                    metrics.request_cancelled();
                    (req.reply)(Err(ServeError::Cancelled));
                }
                continue;
            }

            let (cached, cache_enabled) = {
                let mut c = cache.lock().expect("cache poisoned");
                (c.get(&key), c.enabled())
            };
            // Pre-serve wait snapshot: `queue_seconds` means "wait from
            // submission until this shard started serving the item" on
            // EVERY path — the cache-hit path must not report reply-loop
            // time (or an earlier group member's slow reply callback) as
            // queue wait. The measurement path (cache disabled) times
            // each request immediately before its own execution instead,
            // so it skips this allocation entirely.
            let waits: Vec<f64> = if cache_enabled {
                group.iter()
                    .map(|r| r.enqueued.elapsed().as_secs_f64())
                    .collect()
            } else {
                Vec::new()
            };
            if let Some(output) = cached {
                metrics.cache_hit(batch_size as u64);
                for (req, wait) in group.into_iter().zip(waits) {
                    let latency = req.enqueued.elapsed().as_secs_f64();
                    metrics.request_completed(latency);
                    (req.reply)(Ok(ServeReply {
                        shard: label.clone(),
                        output: output.clone(),
                        batch_size,
                        queue_seconds: wait,
                        cache_hit: true,
                        worker,
                    }));
                }
                continue;
            }
            if cache_enabled {
                // Serving semantics: equal work keys are interchangeable
                // — ONE execution answers the whole group and seeds the
                // cache.
                metrics.cache_miss(batch_size as u64);
                match backend.run(&group[0].item) {
                    Ok(output) => {
                        observe_native_compute(&metrics, &label,
                                               &output);
                        cache.lock().expect("cache poisoned")
                            .put(key, output.clone());
                        for (req, wait) in group.into_iter().zip(waits) {
                            let latency =
                                req.enqueued.elapsed().as_secs_f64();
                            metrics.request_completed(latency);
                            (req.reply)(Ok(ServeReply {
                                shard: label.clone(),
                                output: output.clone(),
                                batch_size,
                                queue_seconds: wait,
                                cache_hit: false,
                                worker,
                            }));
                        }
                    }
                    Err(msg) => {
                        for req in group {
                            metrics.request_failed();
                            (req.reply)(Err(ServeError::Backend(
                                msg.clone())));
                        }
                    }
                }
            } else {
                // Measurement semantics (cache disabled — the Scheduler
                // and GemmService shims): EVERY request executes, so
                // per-request timings are real observations, never a
                // duplicated clone. Batching still amortises queue
                // churn and is reported via batch_size.
                for req in group {
                    let wait = req.enqueued.elapsed().as_secs_f64();
                    match backend.run(&req.item) {
                        Ok(output) => {
                            observe_native_compute(&metrics, &label,
                                                   &output);
                            let latency =
                                req.enqueued.elapsed().as_secs_f64();
                            metrics.request_completed(latency);
                            (req.reply)(Ok(ServeReply {
                                shard: label.clone(),
                                output,
                                batch_size,
                                queue_seconds: wait,
                                cache_hit: false,
                                worker,
                            }));
                        }
                        Err(msg) => {
                            metrics.request_failed();
                            (req.reply)(Err(ServeError::Backend(msg)));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;
    use crate::sim::TuningPoint;

    fn knl_point(t: u64) -> WorkItem {
        WorkItem::point(TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                         Precision::F64, 1024, t, 1))
    }

    #[test]
    fn sim_call_roundtrip() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let reply = serve.call(knl_point(64)).unwrap();
        assert_eq!(reply.shard, "sim:knl");
        assert!(!reply.cache_hit);
        match reply.output {
            Output::Sim { record, .. } => assert!(record.gflops > 0.0),
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn cache_hits_on_repeat() {
        let cfg = ServeConfig { cache_cap: 16, ..Default::default() };
        let serve = Serve::start(cfg).unwrap();
        let first = serve.call(knl_point(32)).unwrap();
        assert!(!first.cache_hit);
        let second = serve.call(knl_point(32)).unwrap();
        assert!(second.cache_hit);
        assert!(serve.metrics.cache_hits() >= 1);
        assert!(serve.metrics.cache_hit_rate() > 0.0);
        serve.shutdown();
    }

    #[test]
    fn submit_after_close_gets_explicit_error() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        serve.close();
        let rx = serve.submit(knl_point(16));
        assert!(matches!(rx.recv().unwrap(), Err(ServeError::Closed)));
        assert!(matches!(serve.try_submit(knl_point(16)),
                         Err(ServeError::Closed)));
        serve.shutdown();
    }

    #[test]
    fn cancel_replies_cancelled_not_silence() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        serve.cancel();
        let rx = serve.submit(knl_point(64));
        match rx.recv().unwrap() {
            Err(ServeError::Cancelled) | Ok(_) => {} // race with dispatch
            other => panic!("unexpected {other:?}"),
        }
        assert!(serve.cancelled());
        serve.shutdown();
    }

    #[test]
    fn native_unconfigured_is_explicit_backend_error() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let err = serve
            .call(WorkItem::artifact("dot_n64_f32"))
            .unwrap_err();
        match err {
            ServeError::Backend(m) => {
                assert!(m.contains("no native backend"), "{m}");
            }
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn synthetic_native_shard_serves() {
        let cfg = ServeConfig {
            cache_cap: 8,
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n64_f32".to_string(),
            ])),
            ..Default::default()
        };
        let serve = Serve::start(cfg).unwrap();
        let r = serve.call(WorkItem::artifact("dot_n64_f32"))
            .unwrap();
        assert_eq!(r.shard, "native:pjrt");
        match r.output {
            Output::Native { seconds, engine, .. } => {
                assert!(seconds > 0.0);
                assert_eq!(engine, NativeEngine::HostGemm);
            }
            other => panic!("unexpected {other:?}"),
        }
        let again = serve.call(WorkItem::artifact("dot_n64_f32"))
            .unwrap();
        assert!(again.cache_hit);
        // the same artifact on the NAMED second native shard: computed
        // by the threadpool GEMM, oracle-checked inside the backend
        let tp = serve.call(WorkItem::artifact_on(
            "dot_n64_f32", NativeEngineId::Threadpool)).unwrap();
        assert_eq!(tp.shard, "native:threadpool");
        match tp.output {
            Output::Native { engine, .. } => {
                assert_eq!(engine, NativeEngine::ThreadpoolGemm);
            }
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn bad_synthetic_ids_rejected_at_start() {
        let cfg = ServeConfig {
            native: Some(NativeConfig::Synthetic(vec![
                "mlp_b32_f32".to_string(),
            ])),
            ..Default::default()
        };
        assert!(Serve::start(cfg).is_err());
    }

    #[test]
    fn quota_rejection_is_explicit_and_counted() {
        // quota 0 = every request shed: fully deterministic
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::RejectOverQuota,
            shard_quota: Some(0),
            ..Default::default()
        }).unwrap();
        let err = serve.call(knl_point(32)).unwrap_err();
        match err {
            ServeError::Overloaded { shard, depth, quota } => {
                assert_eq!(shard, "sim:knl");
                assert_eq!(depth, 0);
                assert_eq!(quota, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(serve.metrics.shed(), 1);
        assert!(serve.metrics.shed_rate() > 0.0);
        assert!(serve.summary().contains("1 shed"));
        serve.shutdown();
    }

    #[test]
    fn quota_ignored_without_a_rejecting_policy() {
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::None,
            shard_quota: Some(0),
            ..Default::default()
        }).unwrap();
        assert!(serve.call(knl_point(32)).is_ok(),
                "policy None must never shed");
        assert_eq!(serve.metrics.shed(), 0);
        serve.shutdown();
    }

    #[test]
    fn expired_deadline_is_shed_at_dequeue() {
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::ShedExpired,
            ..Default::default()
        }).unwrap();
        // deadline = submission instant: expired by dequeue time
        let item = knl_point(64).with_deadline(Instant::now());
        match serve.call(item).unwrap_err() {
            ServeError::Overloaded { shard, quota, .. } => {
                assert_eq!(shard, "sim:knl");
                assert_eq!(quota, 0, "no quota configured");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(serve.metrics.shed(), 1);
        // a live deadline sails through
        let ok = serve.call(knl_point(64).with_deadline_in(
            std::time::Duration::from_secs(3600)));
        assert!(ok.is_ok());
        serve.shutdown();
    }

    #[test]
    fn deadlines_inert_without_expiry_policy() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let item = knl_point(16).with_deadline(Instant::now());
        assert!(serve.call(item).is_ok(),
                "ShedPolicy::None must ignore deadlines");
        serve.shutdown();
    }

    #[test]
    fn live_summary_sees_shard_depths_mid_run() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        for t in [16u64, 32, 64] {
            serve.call(knl_point(t)).unwrap();
        }
        // Mid-run (NOT shutdown): the registry walk must surface the
        // shard queue's high-water mark; requests flowed through the
        // queue, so it is at least 1.
        assert!(serve.metrics.shard_depth_high_water() <= 1,
                "precondition: nothing folded before summary()");
        let _ = serve.summary();
        assert!(serve.metrics.shard_depth_high_water() >= 1,
                "live summary must fold shard depths");
        let depths = serve.shard_depths();
        assert_eq!(depths.len(), 1);
        assert_eq!(depths[0].0, "sim:knl");
        assert!(depths[0].2 >= 1, "high-water from live registry");
        serve.shutdown();
    }

    #[test]
    fn cache_hit_queue_seconds_is_pre_serve_wait_not_reply_time() {
        // Regression for the queue_seconds semantics bug: the cache-hit
        // path used to report full end-to-end latency (measured at
        // reply time, AFTER earlier group members' reply callbacks ran)
        // as the queue wait. Slow reply callbacks of earlier group
        // members must not inflate later members' queue_seconds.
        use std::sync::mpsc::channel;
        let serve = Serve::start(ServeConfig {
            cache_cap: 16,
            max_batch: 8,
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n64_f32".to_string(),
                "gemm_n512_t16_e1_f32".to_string(),
            ])),
            ..Default::default()
        }).unwrap();
        // warm the cache for the small artifact
        serve.call(WorkItem::artifact("dot_n64_f32")).unwrap();
        // Occupy the single pjrt shard worker with slow work (n=512
        // host GEMM, ≫ 20ms); give the worker a moment to dequeue it
        // ALONE, then queue three hits behind it so they coalesce into
        // one later batch.
        let slow = serve.submit(
            WorkItem::artifact("gemm_n512_t16_e1_f32"));
        std::thread::sleep(std::time::Duration::from_millis(5));
        let (tx, rx) = channel();
        for i in 0..3 {
            let tx = tx.clone();
            serve.submit_with(
                WorkItem::artifact("dot_n64_f32"),
                Box::new(move |r| {
                    if i == 0 {
                        // a deliberately slow reply callback
                        std::thread::sleep(
                            std::time::Duration::from_millis(80));
                    }
                    let _ = tx.send((i, r));
                }));
        }
        drop(tx);
        let mut replies: Vec<_> = rx.iter().collect();
        replies.sort_by_key(|(i, _)| *i);
        assert_eq!(replies.len(), 3);
        let waits: Vec<f64> = replies
            .iter()
            .map(|(_, r)| r.as_ref().unwrap().queue_seconds)
            .collect();
        // All three were served from cache in ONE group, so their
        // pre-serve waits differ only by their sub-millisecond submit
        // spacing. Member 0's 80ms reply callback must NOT appear in
        // members 1 and 2's queue wait (the old code measured at reply
        // time, after that callback).
        for (i, w) in waits.iter().enumerate().skip(1) {
            assert!(*w <= waits[0] + 0.060,
                    "hit member {i} queue_seconds {w}s vs member 0 \
                     {}s: includes reply time of earlier members",
                    waits[0]);
        }
        let _ = slow.recv().unwrap().unwrap();
        serve.shutdown();
    }

    #[test]
    fn shutdown_drains_all_pending_requests() {
        let serve = Serve::start(ServeConfig {
            front_cap: 64,
            ..Default::default()
        }).unwrap();
        let rxs: Vec<_> = (0..24)
            .map(|i| serve.submit(knl_point([16, 32, 64][i % 3])))
            .collect();
        serve.shutdown(); // must drain, not drop
        let mut ok = 0;
        for rx in rxs {
            match rx.recv().expect("explicit reply even after shutdown") {
                Ok(_) => ok += 1,
                Err(e) => panic!("pre-shutdown request failed: {e}"),
            }
        }
        assert_eq!(ok, 24, "zero silent drops on shutdown");
    }
}
