//! The unified serve layer — ONE admission-controlled front queue, ONE
//! dispatcher, per-backend **shards**, cross-request **batching**, an
//! LRU **result cache** and unified **metrics**, shared by everything
//! that executes work in this repo.
//!
//! Before this module existed the repo had two disjoint concurrency
//! stacks: `coordinator::Scheduler` (sweep jobs over simulated
//! machines) and `runtime::GemmService` (PJRT artifact serving), each
//! with its own queue, worker loop and counters. The paper's own thesis
//! — one implementation, tuned per backend — applies to the serving
//! plane too, so both are now thin shims over this layer.
//!
//! # Architecture
//!
//! ```text
//!  clients ──submit──▶ front BoundedQueue (admission control)
//!                          │ dispatcher thread (+ per-shard quotas)
//!            ┌─────────────┼──────────────┬──────────────┐
//!            ▼             ▼              ▼              ▼
//!      shard sim:knl  shard sim:…   shard native:pjrt  shard
//!      (N threads)    (N threads)   (1 thread — the    native:threadpool
//!            │             │         PJRT client is    (1 worker over an
//!            ▼             ▼         Rc-based)          M-thread pool)
//!       pop_batch → shed expired → group by work key → LRU cache
//!                          │                              → Backend::run
//!                          └──▶ reply channels + ServeMetrics
//! ```
//!
//! * **Admission**: `submit` blocks while the front queue is full
//!   (backpressure) and fails *explicitly* with [`ServeError::Closed`]
//!   after shutdown — a request is never silently dropped.
//! * **Overload control**: with a [`ShedPolicy`] configured, a shard
//!   whose outstanding line reached `ServeConfig::shard_quota` sheds
//!   new arrivals with [`ServeError::Overloaded`] at routing time, and
//!   (policy `ShedExpired`) items whose [`WorkItem`] deadline passed
//!   are shed at dequeue — overload is never a silent drop NOR an
//!   unbounded block.
//! * **Shards**: created lazily by the dispatcher, one per simulated
//!   [`ArchId`](crate::arch::ArchId) plus one per **named** native
//!   engine ([`NativeEngineId`]): `native:pjrt` (single-owner PJRT,
//!   host reference-GEMM fallback) and `native:threadpool` (row-blocked
//!   host GEMM over [`crate::util::threadpool::ThreadPool`],
//!   oracle-checked per run) — plus, with online tuning enabled, the
//!   background `tune:explore` shard (see [`crate::autotune`]).
//! * **Online autotuning**: with `ServeConfig::tuning_store` /
//!   `online_tune` set, the native backends select each request's
//!   [`KernelParams`](crate::gemm::kernel::KernelParams) from the
//!   persistent [`TuningStore`](crate::autotune::TuningStore)
//!   (replies labelled `…@store`), and the dispatcher seeds bounded
//!   background explorations for untuned `(dtype, bucket)`s —
//!   strictly non-blocking (over the tuner's hard line bound the job
//!   is shed and counted, never queued in front of serving traffic).
//! * **Adaptive quotas**: with a rejecting [`ShedPolicy`] and
//!   `shard_quota: None`, each shard's quota is derived live from its
//!   service-rate EWMA × `ServeConfig::latency_budget` (surfaced in
//!   [`Serve::summary`]).
//! * **Batching**: shard workers drain up to `max_batch` requests in one
//!   `pop_batch`, group them by work key, and serve each group with one
//!   backend execution.
//! * **Caching**: per-shard LRU keyed by the canonical work-item key;
//!   disabled (capacity 0) for measurement-oriented callers. With
//!   [`ServeConfig::result_cache_path`] set, executed **native**
//!   results additionally spill to a persistent on-disk cache
//!   (atomic-write + corrupt-recovery, keyed by artifact identity
//!   digest); replies label the tier ([`ServeReply::cache_src`]:
//!   `cache:mem` / `cache:disk`).
//! * **Client plane**: [`Serve::submit_handle`] is the submission
//!   primitive (a [`ReplyHandle`] future); the callback and channel
//!   APIs are thin adapters over it, and `crate::client` layers
//!   sessions (windowed, exactly-accounted, session-tagged — the
//!   dispatcher round-robins routing bursts across sessions and the
//!   metrics keep per-session tallies) and request pipelines on top.
//! * **Shutdown**: `close` stops admission; queued work is drained,
//!   executed and replied to before workers exit. `cancel` short-cuts
//!   execution but still replies ([`ServeError::Cancelled`]).

pub mod backend;
pub mod cache;
pub mod diskcache;
pub mod fault;
pub mod loadgen;
pub mod metrics;
pub mod trace;

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::autotune::{bucket_for, SharedTuningStore, TunerBackend,
                      TuningStore};
use crate::client::future::{pair, ReplyHandle};
use crate::coordinator::queue::BoundedQueue;
use crate::gemm::Precision;
use crate::runtime::artifact::Manifest;
use crate::util::prng::{seed_for, SplitMix64};
use crate::util::threadpool::panic_message;

pub use backend::{Backend, BackendFactory, BackendFailure, MachinePark,
                  NativeBackend, NativeEngine, NativeEngineId, Output,
                  ShardKey, SimBackend, ThreadpoolGemm, WorkItem,
                  WorkPayload};
pub use cache::LruCache;
pub use diskcache::DiskResultCache;
pub use fault::{Admission, FaultPlan, FaultSite, Quarantine,
                QuarantinePolicy, RetryPolicy};
pub use metrics::{ServeMetrics, SessionOutcome, SessionTally};
pub use trace::{ActiveTrace, SpanKind, TraceRecord, TraceRecorder};

use trace::attach_err;

/// Why a request did not produce an output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The serve layer is shut down; the request was rejected at
    /// admission (explicitly — never a dangling channel).
    Closed,
    /// `cancel()` was called before this request executed.
    Cancelled,
    /// Overload control shed this request — the shard's admission
    /// quota was exceeded, or the item's deadline expired before
    /// execution started. Always an explicit reply: overload is never
    /// a silent drop, and (with a shed policy configured) never an
    /// unbounded block either.
    Overloaded {
        /// Label of the shard that was overloaded (e.g. `native:pjrt`).
        shard: String,
        /// Outstanding depth observed at the shed decision.
        depth: usize,
        /// The configured per-shard quota (0 when shedding was
        /// triggered by deadline expiry with no quota set).
        quota: usize,
    },
    /// The backend refused or failed the request.
    Backend(String),
    /// The backend's output failed its oracle digest check — the
    /// result is wrong, not merely absent. Discriminated from
    /// [`ServeError::Backend`] so retry and quarantine can treat
    /// corruption as evidence against the *artifact*, not the shard.
    Corrupted {
        /// Label of the shard whose execution produced the corrupt
        /// output.
        shard: String,
        /// Identity of the artifact whose result failed validation.
        artifact: String,
    },
    /// The artifact's circuit breaker is open (K consecutive
    /// post-retry failures): the request failed fast without touching
    /// a shard. A half-open probe after the cooldown re-validates (see
    /// [`fault::Quarantine`]).
    Quarantined {
        /// Identity of the quarantined artifact.
        artifact: String,
    },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Closed => {
                write!(f, "serve layer closed: request rejected")
            }
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::Overloaded { shard, depth, quota } => {
                write!(f, "shard {shard} overloaded (depth {depth}, \
                           quota {quota}): request shed")
            }
            ServeError::Backend(m) => write!(f, "{m}"),
            ServeError::Corrupted { shard, artifact } => {
                write!(f, "corrupted result from {shard} for artifact \
                           {artifact}: oracle digest mismatch")
            }
            ServeError::Quarantined { artifact } => {
                write!(f, "artifact {artifact} is quarantined: failed \
                           fast without execution")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What the serve layer does when a shard is past its admission quota
/// or a request's deadline has expired. Orthogonal to every other knob:
/// the default (`None`) is PR-1 behavior — pure backpressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Never shed: requests queue (bounded by queue capacities and the
    /// dispatcher's overflow buffers) and block producers when full.
    None,
    /// Reject with [`ServeError::Overloaded`] at routing time when a
    /// shard's outstanding depth (its queue + its overflow line) has
    /// reached `ServeConfig::shard_quota`.
    RejectOverQuota,
    /// [`ShedPolicy::RejectOverQuota`] *plus* shed items whose deadline
    /// has already expired when a shard worker dequeues them (the work
    /// would be wasted — its result can no longer arrive in time).
    ShedExpired,
}

impl ShedPolicy {
    pub fn rejects_over_quota(&self) -> bool {
        matches!(self, ShedPolicy::RejectOverQuota
                     | ShedPolicy::ShedExpired)
    }

    pub fn sheds_expired(&self) -> bool {
        matches!(self, ShedPolicy::ShedExpired)
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(ShedPolicy::None),
            "reject" => Some(ShedPolicy::RejectOverQuota),
            "expire" => Some(ShedPolicy::ShedExpired),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ShedPolicy::None => "none",
            ShedPolicy::RejectOverQuota => "reject",
            ShedPolicy::ShedExpired => "expire",
        }
    }
}

/// Where a reply's result came from — surfaced per reply
/// ([`ServeReply::cache_src`], labels `cache:mem` / `cache:disk`) and
/// in the metrics, so the two cache tiers are attributable separately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheSource {
    /// Executed by the backend (no cache involvement).
    Miss,
    /// Served from the shard's in-memory LRU.
    Mem,
    /// Served from the persistent on-disk result cache
    /// (`ServeConfig::result_cache_path`).
    Disk,
}

impl CacheSource {
    pub fn is_hit(&self) -> bool {
        !matches!(self, CacheSource::Miss)
    }

    pub fn label(&self) -> &'static str {
        match self {
            CacheSource::Miss => "exec",
            CacheSource::Mem => "cache:mem",
            CacheSource::Disk => "cache:disk",
        }
    }
}

/// A served request's full story.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// Label of the shard that served it (e.g. `sim:knl`,
    /// `native:pjrt`, `native:threadpool`).
    pub shard: String,
    pub output: Output,
    /// Size of the coalesced group this request was served in.
    pub batch_size: usize,
    /// Wait from submission to the start of execution, seconds.
    pub queue_seconds: f64,
    /// Whether the result came from a cache (either tier —
    /// `cache_src` has the split).
    pub cache_hit: bool,
    /// Which tier answered: executed, memory LRU, or disk.
    pub cache_src: CacheSource,
    /// Worker index within the shard.
    pub worker: usize,
    /// Execution attempts this reply took (1 = first try; > 1 means
    /// the retry policy recovered it). Cache hits execute nothing and
    /// report 1.
    pub attempts: u32,
}

/// The one reply type every client-plane surface resolves to.
pub type ServeResult = Result<ServeReply, ServeError>;

pub type ReplyRx = Receiver<ServeResult>;

/// Reply continuation, invoked exactly once per request — by a shard
/// worker, or by the admission path on rejection. Adapters (the
/// Scheduler/GemmService shims) use this to translate the reply type
/// without forwarder threads.
pub type ReplyFn = Box<dyn FnOnce(Result<ServeReply, ServeError>) + Send>;

struct ServeRequest {
    item: WorkItem,
    reply: ReplyFn,
    enqueued: Instant,
    /// Dispatcher-synthesized background work (tuning explorations):
    /// executes and replies like any request, but is excluded from the
    /// user-facing request metrics (completed/failed/latency) — it was
    /// never submitted, so counting it would break the
    /// `submitted == ok + shed + failed` accounting.
    internal: bool,
    /// Per-request span tree, opened at admission when the recorder is
    /// enabled (`trace_cap > 0`). `None` on the zero-cost default path
    /// and for dispatcher-synthesized tuning work. The trace commits
    /// exactly once, from the wrapped reply closure — every terminal
    /// path (admission reject, quarantine deny, shed, drain, normal
    /// reply) funnels through it.
    trace: Option<Arc<ActiveTrace>>,
}

/// Where the native shard gets its artifacts.
#[derive(Debug, Clone)]
pub enum NativeConfig {
    /// Load `manifest.json` from this directory (PJRT path, with host
    /// reference-GEMM fallback when device execution is unavailable).
    Artifacts(PathBuf),
    /// Manifest-less synthetic catalog from parseable artifact ids
    /// (host reference GEMM only) — for load tests without artifacts.
    Synthetic(Vec<String>),
}

/// Serve-layer tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Front (admission) queue capacity.
    pub front_cap: usize,
    /// Per-shard queue capacity.
    pub shard_cap: usize,
    /// Maximum requests coalesced per `pop_batch`.
    pub max_batch: usize,
    /// LRU result-cache entries per shard; 0 disables caching
    /// (measurement-oriented callers must re-execute every request).
    pub cache_cap: usize,
    /// Persistent result cache: when set (and `cache_cap > 0`),
    /// executed **native** results spill to this JSON file (atomic
    /// temp-file+rename writes, corrupt-file recovery — the tuning
    /// store's machinery) keyed by work key + artifact identity
    /// digest, and shard workers probe it after a memory-LRU miss.
    /// Disk hits are labelled `cache:disk` in replies and counted
    /// separately in the metrics.
    pub result_cache_path: Option<PathBuf>,
    /// Maximum entries the persistent result cache keeps (0 =
    /// unbounded). Inserts evict oldest-first past the cap, so the
    /// spill file cannot grow without bound; evictions are counted in
    /// the metrics (`cache_evictions_disk`).
    pub result_cache_cap: usize,
    /// Worker threads per simulated shard (each native shard has
    /// exactly one shard worker — the PJRT client is single-owner, and
    /// the threadpool shard parallelizes *inside* its backend).
    pub sim_threads: usize,
    pub native: Option<NativeConfig>,
    /// Threads inside the `native:threadpool` backend's worker pool
    /// (0 = host-sized).
    pub native_threads: usize,
    /// Overload behavior; see [`ShedPolicy`].
    pub shed: ShedPolicy,
    /// Per-shard admission quota: a shard with this many outstanding
    /// requests (its queue plus its overflow line) sheds new arrivals
    /// when the policy rejects over quota. `None` +
    /// [`ShedPolicy::RejectOverQuota`] = **adaptive**: the dispatcher
    /// derives each shard's quota from an EWMA of its observed service
    /// rate × [`latency_budget`] (shards without observations never
    /// shed). `None` under any other policy = unlimited admission —
    /// in particular `ShedPolicy::ShedExpired` without a quota keeps
    /// meaning deadline shedding only.
    ///
    /// [`latency_budget`]: ServeConfig::latency_budget
    pub shard_quota: Option<usize>,
    /// Target queueing budget for **adaptive** quotas: a shard's
    /// derived quota is how many requests it can serve within this
    /// budget at its observed service rate. Ignored when
    /// `shard_quota` is explicit or the policy never rejects.
    pub latency_budget: Duration,
    /// Path of the persistent [`TuningStore`]. When set, the native
    /// backends serve each request with the store's measured-best
    /// [`KernelParams`](crate::gemm::kernel::KernelParams) for its
    /// `(dtype, shape bucket)` (labelled `…@store` in replies).
    pub tuning_store: Option<PathBuf>,
    /// Enable the background `tune:explore` shard: requests for
    /// untuned buckets seed bounded exploration jobs whose winners are
    /// committed to the store (an in-memory store when `tuning_store`
    /// is unset). Serving traffic never blocks on tuning.
    pub online_tune: bool,
    /// Evaluation budget per exploration job (candidate blockings
    /// timed; NOT the full grid).
    pub tune_budget: usize,
    /// Best-of-k timing repetitions per explored candidate.
    pub tune_reps: usize,
    /// Deterministic fault injection (chaos testing): when set, the
    /// named [`FaultSite`]s fire with the plan's seeded probabilities.
    /// `None` (the default) leaves every site inert.
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// Budgeted retry of `Backend`/`Corrupted` execution failures
    /// (including caught worker panics) by the shard workers. The
    /// default (`max_attempts` 1) disables retry. `Overloaded` and
    /// `Closed` are never retried.
    pub retry: RetryPolicy,
    /// Artifact circuit breaker: after `threshold` consecutive
    /// post-retry execution failures an artifact is quarantined
    /// (requests fail fast with [`ServeError::Quarantined`]) until a
    /// half-open probe re-validates it. `threshold` 0 (the default)
    /// disables quarantine.
    pub quarantine: QuarantinePolicy,
    /// Flight-recorder ring capacity (committed traces retained). 0
    /// (the default) disables tracing entirely: no trace ids are
    /// minted, no spans recorded — requests pay one `Option` check.
    pub trace_cap: usize,
    /// Slowest-trace exemplars the recorder retains past ring
    /// overflow (failed/quarantined traces are always retained, up to
    /// the ring capacity).
    pub trace_exemplars: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { front_cap: 64, shard_cap: 64, max_batch: 8, cache_cap: 0,
               result_cache_path: None, result_cache_cap: 1024,
               sim_threads: 1, native: None, native_threads: 4,
               shed: ShedPolicy::None, shard_quota: None,
               latency_budget: Duration::from_millis(250),
               tuning_store: None, online_tune: false, tune_budget: 6,
               tune_reps: 2, fault_plan: None,
               retry: RetryPolicy::default(),
               quarantine: QuarantinePolicy::default(),
               trace_cap: 0, trace_exemplars: 8 }
    }
}

/// Read-only after start; shared via `Arc` so the two named native
/// shards draw from one copy instead of cloning the whole manifest
/// into each factory.
enum NativeSource {
    Manifest(Manifest),
    Synthetic(Vec<String>),
}

/// The persistent result cache plus the artifact identity digests it
/// validates entries against — shared by every native shard worker.
/// Lookup/commit are short-mutex; file writes happen OUTSIDE the lock
/// (snapshot + atomic rename) and are **debounced**: the in-memory
/// insert is synchronous, but the full-file rewrite runs only every
/// [`DISK_FLUSH_EVERY`] puts plus once at dispatcher shutdown — an
/// executed request never pays an O(entries) serialize + rename per
/// result (the same discipline as the tuning-store commit path).
pub(crate) struct SharedDiskCache {
    cache: Mutex<DiskResultCache>,
    /// Work key → identity digest (id, shape, dtype, seeds, coeffs) of
    /// the artifact the layer would execute for that key. Read-only
    /// after start.
    digests: HashMap<String, String>,
    /// Puts since the last flush (crash-loss window bound).
    unflushed: std::sync::atomic::AtomicUsize,
    /// Fault injection for the disk tier's I/O (reads degrade to
    /// misses, writes skip the spill — never a caller-visible error).
    plan: Option<Arc<FaultPlan>>,
}

/// How many disk-cache puts may accumulate before the file is
/// rewritten mid-run (shutdown always flushes the remainder).
const DISK_FLUSH_EVERY: usize = 16;

impl SharedDiskCache {
    /// Disk entries are namespaced per shard (like the per-shard
    /// memory LRUs): the work key alone is engine-agnostic
    /// (`artifact:<id>` for BOTH named native shards), and a pjrt
    /// result replayed to a threadpool request would skip that
    /// shard's oracle check and misattribute engine/kernel.
    fn qualified(shard: &str, key: &str) -> String {
        format!("{shard}|{key}")
    }

    fn get(&self, shard: &str, key: &str,
           trace: Option<&Arc<ActiveTrace>>) -> Option<Output> {
        // An injected read failure behaves exactly like a real one:
        // the probe misses (counted by the caller as an ordinary
        // cache miss) and the request re-executes — disk-tier I/O
        // trouble is NEVER an error to the caller. The trace still
        // learns `fault=disk-read`, so a chaos run's "why did this
        // miss" is answerable from the exemplar alone.
        if self.plan.as_ref()
            .is_some_and(|p| {
                p.should_fire_traced(FaultSite::DiskCacheRead, trace)
            })
        {
            return None;
        }
        let digest = self.digests.get(key)?;
        self.cache.lock().ok()?
            .get(&Self::qualified(shard, key), digest)
    }

    /// Returns how many entries the cache's bound evicted (0 when
    /// nothing was stored or the cap was not hit).
    fn put(&self, shard: &str, key: &str, output: &Output,
           trace: Option<&Arc<ActiveTrace>>) -> u64 {
        use std::sync::atomic::Ordering;

        let Some(digest) = self.digests.get(key) else { return 0 };
        let (evicted, snapshot) = {
            let Ok(mut g) = self.cache.lock() else { return 0 };
            let Some(evicted) =
                g.put(&Self::qualified(shard, key), digest, output)
            else {
                return 0;
            };
            let snap = if self.unflushed
                .fetch_add(1, Ordering::Relaxed) + 1
                >= DISK_FLUSH_EVERY
            {
                self.unflushed.store(0, Ordering::Relaxed);
                g.snapshot()
            } else {
                None
            };
            (evicted, snap)
        };
        self.write(snapshot, trace);
        evicted
    }

    /// Persist the current contents (shutdown path — drains the
    /// debounce window so a clean exit loses nothing).
    fn flush(&self) {
        use std::sync::atomic::Ordering;

        let snapshot = {
            let Ok(g) = self.cache.lock() else { return };
            if self.unflushed.swap(0, Ordering::Relaxed) == 0 {
                return; // nothing new since the last write
            }
            g.snapshot()
        };
        self.write(snapshot, None);
    }

    fn write(&self, snapshot: Option<(PathBuf, String)>,
             trace: Option<&Arc<ActiveTrace>>) {
        let Some((path, json)) = snapshot else { return };
        // An injected write failure fails like a real one: the spill
        // is skipped wholesale (write_atomic's temp-file + rename
        // discipline means a mid-write failure leaves no partial
        // file either way) and the in-memory entries stay live — the
        // cache remains fully usable, only cross-restart persistence
        // of this window is lost.
        if self.plan.as_ref()
            .is_some_and(|p| {
                p.should_fire_traced(FaultSite::DiskCacheWrite, trace)
            })
        {
            eprintln!("[serve] injected disk-cache write failure: \
                       spill to {} skipped", path.display());
            return;
        }
        if let Err(e) = TuningStore::write_atomic(&path, &json) {
            // in-memory entries took effect; only cross-restart
            // persistence is lost — never fail the serving path
            eprintln!("[serve] result cache could not be persisted \
                       to {}: {e:#}", path.display());
        }
    }
}

/// Work key → identity digest for everything the native source can
/// serve (the disk cache refuses entries whose recorded digest
/// differs — a changed manifest under the same id is a miss).
fn native_digests(src: &Option<Arc<NativeSource>>)
                  -> HashMap<String, String> {
    let mut digests = HashMap::new();
    match src.as_deref() {
        None => {}
        Some(NativeSource::Manifest(m)) => {
            for meta in &m.artifacts {
                let spec = backend::spec_from_meta(meta);
                digests.insert(
                    WorkItem::artifact(spec.id.as_str()).cache_key(),
                    backend::spec_digest(&spec));
                // Model-plane node ids get identity digests too, from
                // the same content the backend serves — a changed
                // model under the same id invalidates its disk-cache
                // entries and gets a fresh quarantine breaker per
                // node. Unservable mlp entries are skipped here
                // exactly like the backend skips them.
                let Ok(ms) = crate::model::ModelSpec::from_meta(meta)
                else { continue };
                use crate::model::NodeKind;
                for (l, layer) in ms.layers.iter().enumerate() {
                    let mut kinds = vec![NodeKind::Fused,
                                         NodeKind::Strict,
                                         NodeKind::GemmOnly];
                    if layer.activation {
                        kinds.push(NodeKind::Activation);
                    }
                    for kind in kinds {
                        digests.insert(
                            WorkItem::artifact(ms.node_id(l, kind))
                                .cache_key(),
                            ms.node_descriptor(l, kind));
                    }
                }
            }
        }
        Some(NativeSource::Synthetic(ids)) => {
            // ids were validated at start; an error here cannot happen
            if let Ok(catalog) = backend::synthetic_catalog(ids) {
                for spec in catalog.values() {
                    digests.insert(
                        WorkItem::artifact(spec.id.as_str())
                            .cache_key(),
                        backend::spec_digest(spec));
                }
            }
        }
    }
    digests
}

struct ShardHandle {
    queue: Arc<BoundedQueue<ServeRequest>>,
    workers: Vec<JoinHandle<()>>,
}

/// Live registry of shard queues (label → queue), shared between the
/// dispatcher (which registers shards as it spawns them) and
/// [`Serve::summary`]/[`Serve::shard_depths`] — so a *mid-run* summary
/// sees real per-shard depth high-water marks instead of zeros that
/// only get folded in at shutdown.
type ShardRegistry = Mutex<Vec<(String, Arc<BoundedQueue<ServeRequest>>)>>;

/// Handle to a running serve layer.
pub struct Serve {
    front: Arc<BoundedQueue<ServeRequest>>,
    dispatcher: Option<JoinHandle<()>>,
    pub metrics: Arc<ServeMetrics>,
    cancel: Arc<AtomicBool>,
    park: Arc<MachinePark>,
    shard_queues: Arc<ShardRegistry>,
    store: Option<SharedTuningStore>,
    quarantine: Option<Arc<Quarantine>>,
    recorder: Option<Arc<TraceRecorder>>,
}

impl Serve {
    /// Start the layer. The native manifest (when configured) is loaded
    /// eagerly so configuration errors surface here, not on the first
    /// artifact request; shard threads spawn lazily on first use.
    pub fn start(cfg: ServeConfig) -> crate::Result<Serve> {
        let native_src = match &cfg.native {
            None => None,
            Some(NativeConfig::Artifacts(dir)) => {
                Some(Arc::new(NativeSource::Manifest(
                    Manifest::load(dir)?)))
            }
            Some(NativeConfig::Synthetic(ids)) => {
                // validate ids eagerly
                for id in ids {
                    if backend::parse_artifact_id(id).is_none() {
                        anyhow::bail!(
                            "unsupported synthetic artifact id {id:?}");
                    }
                }
                Some(Arc::new(NativeSource::Synthetic(ids.clone())))
            }
        };
        let front: Arc<BoundedQueue<ServeRequest>> =
            Arc::new(BoundedQueue::new(cfg.front_cap.max(1)));
        let metrics = Arc::new(ServeMetrics::new());
        let cancel = Arc::new(AtomicBool::new(false));
        let park = Arc::new(MachinePark::default());
        let shard_queues: Arc<ShardRegistry> =
            Arc::new(Mutex::new(Vec::new()));
        // Learned performance state: a persistent store when a path is
        // configured; online tuning without one still works against an
        // in-memory store (useful for tests and throwaway layers).
        let store: Option<SharedTuningStore> = match (&cfg.tuning_store,
                                                      cfg.online_tune) {
            (Some(path), _) => {
                Some(Arc::new(Mutex::new(TuningStore::open(path))))
            }
            (None, true) => {
                Some(Arc::new(Mutex::new(TuningStore::in_memory())))
            }
            (None, false) => None,
        };
        // Persistent result cache: opened once, shared by every native
        // shard worker. Only meaningful with the LRU enabled — the
        // measurement-semantics path (cache_cap 0) must re-execute
        // everything, disk included.
        // One digest map for everything that keys by artifact
        // identity: the disk cache's entry validation and the
        // quarantine breaker (one breaker per artifact *content*, not
        // per id string).
        let digests = Arc::new(native_digests(&native_src));
        let disk: Option<Arc<SharedDiskCache>> =
            match (&cfg.result_cache_path, cfg.cache_cap) {
                (Some(path), cap) if cap > 0 => {
                    Some(Arc::new(SharedDiskCache {
                        cache: Mutex::new(DiskResultCache::open(path)
                            .with_cap(cfg.result_cache_cap)),
                        digests: (*digests).clone(),
                        unflushed: std::sync::atomic::AtomicUsize
                            ::new(0),
                        plan: cfg.fault_plan.clone(),
                    }))
                }
                (Some(path), _) => {
                    eprintln!("[serve] result_cache_path {} ignored: \
                               cache_cap is 0 (measurement semantics \
                               re-execute everything)", path.display());
                    None
                }
                (None, _) => None,
            };
        // The artifact circuit breaker is shared between the
        // dispatcher (admission gate) and the shard workers (outcome
        // recording) — and surfaced on the handle for attribution.
        let quarantine: Option<Arc<Quarantine>> =
            if cfg.quarantine.threshold > 0 {
                Some(Arc::new(Quarantine::new(cfg.quarantine)))
            } else {
                None
            };
        // Flight recorder: traces are opened at admission and handed
        // through the pipeline inside the request itself, so the
        // dispatcher/shard paths never consult the recorder — only
        // commit (via the wrapped reply) and the summary do.
        let recorder: Option<Arc<TraceRecorder>> =
            (cfg.trace_cap > 0).then(|| {
                Arc::new(TraceRecorder::new(cfg.trace_cap,
                                            cfg.trace_exemplars))
            });
        let dispatcher = {
            let front = Arc::clone(&front);
            let metrics = Arc::clone(&metrics);
            let cancel = Arc::clone(&cancel);
            let park = Arc::clone(&park);
            let registry = Arc::clone(&shard_queues);
            let store = store.clone();
            let cfg = cfg.clone();
            let quarantine = quarantine.clone();
            std::thread::Builder::new()
                .name("serve-dispatch".into())
                .spawn(move || {
                    dispatch_loop(front, cfg, native_src, store, disk,
                                  park, metrics, cancel, registry,
                                  quarantine, digests)
                })
                .expect("spawn serve dispatcher")
        };
        Ok(Serve { front, dispatcher: Some(dispatcher), metrics, cancel,
                   park, shard_queues, store, quarantine, recorder })
    }

    /// The submission primitive every public surface builds on: push
    /// the request with its reply continuation. The continuation runs
    /// exactly once — with `Err(ServeError::Closed)` synchronously when
    /// admission is already shut down. `pub(crate)` so the client
    /// plane (`client::Session`) can install its accounting closure
    /// without an extra future hop.
    pub(crate) fn submit_raw(&self, item: WorkItem, reply: ReplyFn) {
        self.metrics.request_submitted();
        let (item, trace, reply) = match &self.recorder {
            None => (item, None, reply),
            Some(rec) => {
                // Pre-assigned ids (pipelines) are honored so a DAG's
                // requests share one trace lane; otherwise mint here.
                let mut item = item;
                let id = item.trace_id
                    .unwrap_or_else(|| rec.mint_id());
                item.trace_id = Some(id);
                let trace = rec.begin(id, item.cache_key(),
                                      item.session);
                let commit = Arc::clone(&trace);
                // Commit-on-reply: the exactly-one-reply contract
                // makes the wrapped closure the single terminal point
                // of every trace — admission rejects, quarantine
                // denies, sheds, drains, and normal replies all funnel
                // through it, so no per-site bookkeeping can leak a
                // span or double-close one.
                let reply: ReplyFn = Box::new(move |r| {
                    commit.finish(&r);
                    reply(r)
                });
                (item, Some(trace), reply)
            }
        };
        // Depth high-water comes from the queue's own max_depth (one
        // lock inside push), not a separate len() read per request.
        let req = ServeRequest { item, reply,
                                 enqueued: Instant::now(),
                                 internal: false, trace };
        if let Err(req) = self.front.push_or_return(req) {
            self.metrics.request_failed();
            attach_err(&req.trace, &ServeError::Closed);
            (req.reply)(Err(ServeError::Closed));
        }
    }

    /// Submit a work item and get a [`ReplyHandle`] — the client
    /// plane's future primitive (poll / wait / timeout / `on_ready`
    /// chaining; dropping the pending handle abandons the reply
    /// cleanly). Blocks while the front queue is full (admission
    /// control). The handle ALWAYS resolves with exactly one explicit
    /// result — after shutdown that is `Err(ServeError::Closed)`.
    pub fn submit_handle(&self, item: WorkItem)
                         -> ReplyHandle<ServeResult> {
        let (promise, handle) = pair();
        self.submit_raw(item, Box::new(move |r| {
            // an abandoned (dropped) handle just discards the value —
            // session-tagged callers layer cancellation accounting on
            // top via their own closure (client::Session)
            let _ = promise.complete(r);
        }));
        handle
    }

    /// Submit with a reply continuation — a thin adapter over the
    /// future primitive: `submit_handle(item).on_ready(reply)`.
    pub fn submit_with(&self, item: WorkItem, reply: ReplyFn) {
        self.submit_handle(item).on_ready(move |r| reply(r));
    }

    /// Submit a work item over a channel (the legacy surface). The
    /// returned channel ALWAYS yields exactly one explicit result —
    /// after shutdown that result is `Err(ServeError::Closed)`, never
    /// a dangling disconnect.
    pub fn submit(&self, item: WorkItem) -> ReplyRx {
        let (tx, rx) = channel();
        self.submit_with(item, Box::new(move |r| {
            let _ = tx.send(r);
        }));
        rx
    }

    /// Like [`Serve::submit`] but reports shutdown on the call itself.
    pub fn try_submit(&self, item: WorkItem)
                      -> Result<ReplyRx, ServeError> {
        if self.front.is_closed() {
            self.metrics.request_submitted();
            self.metrics.request_failed();
            return Err(ServeError::Closed);
        }
        Ok(self.submit(item))
    }

    /// Submit and wait (over the future primitive).
    pub fn call(&self, item: WorkItem) -> ServeResult {
        // a broken promise cannot happen (every request gets an
        // explicit reply); recv() maps it to Closed defensively.
        self.submit_handle(item).recv()
    }

    /// Request cancellation: queued work is drained and replied to with
    /// [`ServeError::Cancelled`] instead of executing.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Stop admission (idempotent). Queued requests still complete;
    /// subsequent `submit`s get an explicit `Closed` error.
    pub fn close(&self) {
        self.front.close();
    }

    /// Current front-queue depth (for admission metrics).
    pub fn front_depth(&self) -> usize {
        self.front.len()
    }

    /// High-water mark of the front queue since start (tracked inside
    /// the queue itself — no per-request metric calls on the hot path).
    pub fn front_depth_high_water(&self) -> usize {
        self.front.max_depth()
    }

    /// Unified metrics summary with the queue-depth high-water marks
    /// folded in **at observation time** (they live in the queues until
    /// read) — a mid-run summary reports real shard depths, not the
    /// zeros a shutdown-only fold would show.
    pub fn summary(&self) -> String {
        self.metrics.observe_front_depth(self.front.max_depth());
        // a poisoned registry degrades to "no shard depths folded"
        // rather than panicking the observer thread (R2)
        if let Ok(qs) = self.shard_queues.lock() {
            for (_, q) in qs.iter() {
                self.metrics.observe_shard_depth(q.max_depth());
            }
        }
        let mut s = self.metrics.summary();
        if let Some(rec) = &self.recorder {
            let phases = rec.phase_summary();
            if !phases.is_empty() {
                s.push_str("\n  trace phases: ");
                s.push_str(&phases);
            }
            s.push_str(&format!(
                "\n  traces: {} committed, {} dropped (ring cap {})",
                rec.committed(), rec.dropped(), rec.cap()));
        }
        s
    }

    /// Live per-shard queue visibility: `(label, current depth,
    /// high-water depth)` for every shard spawned so far, **sorted by
    /// label** — spawn order depends on request arrival, which would
    /// make reports built from this nondeterministic across runs.
    pub fn shard_depths(&self) -> Vec<(String, usize, usize)> {
        let Ok(qs) = self.shard_queues.lock() else {
            return Vec::new();
        };
        let mut depths: Vec<_> = qs
            .iter()
            .map(|(label, q)| (label.clone(), q.len(), q.max_depth()))
            .collect();
        drop(qs);
        depths.sort_by(|a, b| a.0.cmp(&b.0));
        depths
    }

    /// The shared machine-model registry (pre-warm, inspection).
    pub fn park(&self) -> &Arc<MachinePark> {
        &self.park
    }

    /// The tuning store this layer selects kernels from (present when
    /// `tuning_store` or `online_tune` was configured). Shared with
    /// the tuner shard — lock briefly.
    pub fn tuning_store(&self) -> Option<SharedTuningStore> {
        self.store.clone()
    }

    /// The artifact circuit breaker (present when
    /// `ServeConfig::quarantine.threshold > 0`) — for attribution:
    /// [`Quarantine::snapshot`] says exactly which artifacts are
    /// isolated and how many consecutive failures got them there.
    pub fn quarantine(&self) -> Option<Arc<Quarantine>> {
        self.quarantine.clone()
    }

    /// The flight recorder (present when `ServeConfig::trace_cap > 0`)
    /// — export surface: ring snapshot, exemplars, phase shares.
    pub fn trace_recorder(&self) -> Option<Arc<TraceRecorder>> {
        self.recorder.clone()
    }

    /// Mint a trace id for pre-assignment: a pipeline tags every
    /// node's `WorkItem` with one id so the whole DAG commits under a
    /// single trace lane. `None` when tracing is off — callers submit
    /// untagged and ids are minted (or not) at admission.
    pub fn mint_trace_id(&self) -> Option<u64> {
        self.recorder.as_ref().map(|r| r.mint_id())
    }

    /// Serve a compiled model plan end to end on a one-shot internal
    /// session — the CLI's `serve --model` unit of work. Callers
    /// serving many plans should hold their own
    /// [`Session`](crate::client::Session) and use
    /// `Session::submit_model`, which keeps the per-session
    /// accounting to one row instead of one per plan.
    pub fn submit_model(&self, plan: &crate::model::ModelPlan)
                        -> crate::model::ModelOutcome {
        let session = crate::client::Session::open(
            self, crate::client::SessionConfig::default());
        let out = session.submit_model(plan);
        session.close();
        out
    }

    /// Digest keys of the artifacts currently quarantined (empty when
    /// quarantine is disabled or nothing is isolated).
    pub fn quarantined(&self) -> Vec<String> {
        self.quarantine
            .as_ref()
            .map(|q| q.quarantined())
            .unwrap_or_default()
    }

    /// Graceful shutdown: close admission, drain, join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.front.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Serve {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Outstanding-line bound of the background tuning shard: at most this
/// many exploration jobs may be *queued* (one more may be executing).
/// Deliberately tiny and non-configurable — the tuner is the lowest
/// priority work in the system, and the dispatcher only ever feeds it
/// with a non-blocking push: over this bound the job is dropped,
/// counted in `ServeMetrics::tune_shed`, and retried by whichever
/// later request finds the bucket still untuned.
const TUNE_QUOTA: usize = 1;

/// Dispatcher-side context for online tuning: the shared store plus
/// the set of `(dtype, bucket)` explorations currently in flight
/// (shared with the jobs' reply closures, which clear their entry so
/// a failed or shed exploration can be retried later).
struct TuneCtx {
    store: SharedTuningStore,
    inflight: Arc<Mutex<HashSet<(Precision, u64)>>>,
    /// Dispatcher-local memo of buckets already found in the store:
    /// once a bucket is tuned it can never become untuned in-process,
    /// so warm traffic skips the store lock entirely (the only
    /// remaining per-request cost on the trigger path is the id
    /// parse, which takes no locks).
    tuned: HashSet<(Precision, u64)>,
}

impl TuneCtx {
    /// Should this request seed a background exploration? Yes iff it
    /// is an artifact in the host range whose `(dtype, bucket)` has no
    /// store entry and no exploration already in flight. On `Some`,
    /// the bucket is marked in flight — release with [`TuneCtx::abort`]
    /// if the job is never enqueued.
    fn wants_explore(&mut self, item: &WorkItem)
                     -> Option<(Precision, u64)> {
        let WorkPayload::Artifact { id, .. } = &item.payload else {
            return None;
        };
        let (n, dtype) = backend::parse_artifact_id(id)?;
        if n > backend::HOST_GEMM_MAX_N {
            return None;
        }
        let bucket = bucket_for(n);
        if self.tuned.contains(&(dtype, bucket)) {
            return None;
        }
        if self.store.lock().ok()?.lookup(dtype, bucket).is_some() {
            self.tuned.insert((dtype, bucket));
            return None;
        }
        if !self.inflight.lock().ok()?.insert((dtype, bucket)) {
            return None;
        }
        Some((dtype, bucket))
    }

    /// Release an in-flight mark whose job was shed or never enqueued.
    fn abort(&self, dtype: Precision, bucket: u64) {
        if let Ok(mut g) = self.inflight.lock() {
            g.remove(&(dtype, bucket));
        }
    }

    /// Build the internal exploration request. Its reply closure
    /// clears the in-flight mark and records the outcome in the tune
    /// counters — never in the user-facing request metrics.
    fn job(&self, dtype: Precision, bucket: u64,
           metrics: &Arc<ServeMetrics>) -> ServeRequest {
        let inflight = Arc::clone(&self.inflight);
        let metrics = Arc::clone(metrics);
        ServeRequest {
            item: WorkItem::explore(dtype, bucket),
            enqueued: Instant::now(),
            internal: true,
            trace: None,
            reply: Box::new(move |r| {
                if let Ok(mut g) = inflight.lock() {
                    g.remove(&(dtype, bucket));
                }
                match r {
                    Ok(_) => metrics.tune_job_completed(),
                    Err(_) => metrics.tune_job_failed(),
                }
            }),
        }
    }
}

/// Fair admission: reorder one routed burst round-robin across the
/// sessions present in it (first-appearance order; per-session FIFO
/// preserved; untagged requests form one lane of their own). A burst
/// from a single lane — the common case — is returned untouched, so
/// legacy single-caller traffic keeps strict FIFO. This is what keeps
/// a greedy session from monopolizing a routing burst: with two
/// sessions in the queue, their requests hit the shard queues (and
/// the per-shard quotas) alternately instead of in arrival runs.
fn interleave_sessions(burst: Vec<ServeRequest>) -> Vec<ServeRequest> {
    use std::collections::VecDeque;

    let mut lanes: Vec<(Option<u64>, VecDeque<ServeRequest>)> =
        Vec::new();
    for req in burst {
        let tag = req.item.session;
        match lanes.iter_mut().find(|(t, _)| *t == tag) {
            Some((_, lane)) => lane.push_back(req),
            None => {
                let mut lane = VecDeque::new();
                lane.push_back(req);
                lanes.push((tag, lane));
            }
        }
    }
    if lanes.len() <= 1 {
        return lanes.pop()
            .map(|(_, lane)| lane.into_iter().collect())
            .unwrap_or_default();
    }
    let total = lanes.iter().map(|(_, lane)| lane.len()).sum();
    let mut out = Vec::with_capacity(total);
    while !lanes.is_empty() {
        lanes.retain_mut(|(_, lane)| {
            if let Some(req) = lane.pop_front() {
                out.push(req);
            }
            !lane.is_empty()
        });
    }
    out
}

/// The quarantine key of an artifact work item: its identity digest
/// when the native source knows it, the raw work key otherwise (an
/// unknown id still gets a stable breaker of its own).
fn quarantine_key(digests: &HashMap<String, String>, item: &WorkItem)
                  -> Option<String> {
    if !matches!(item.payload, WorkPayload::Artifact { .. }) {
        return None;
    }
    let key = item.cache_key();
    Some(digests.get(&key).cloned().unwrap_or(key))
}

#[allow(clippy::too_many_arguments)]
fn dispatch_loop(front: Arc<BoundedQueue<ServeRequest>>, cfg: ServeConfig,
                 native_src: Option<Arc<NativeSource>>,
                 store: Option<SharedTuningStore>,
                 disk: Option<Arc<SharedDiskCache>>,
                 park: Arc<MachinePark>, metrics: Arc<ServeMetrics>,
                 cancel: Arc<AtomicBool>,
                 registry: Arc<ShardRegistry>,
                 quarantine: Option<Arc<Quarantine>>,
                 digests: Arc<HashMap<String, String>>) {
    use std::collections::VecDeque;

    use crate::coordinator::queue::PushRefusal;

    let mut shards: HashMap<ShardKey, ShardHandle> = HashMap::new();
    // Per-shard overflow buffers: when one shard's queue is full, its
    // requests wait HERE instead of blocking the dispatcher — a slow
    // native shard must not head-of-line-block sim traffic sitting
    // behind it in the single front queue. Bounded: past the limit the
    // dispatcher blocks on the saturated shard only (memory stays
    // bounded; other shards were already routed).
    let mut overflow: HashMap<ShardKey, VecDeque<ServeRequest>> =
        HashMap::new();
    let mut overflow_len = 0usize;
    let overflow_limit = cfg.front_cap.max(16) * 4;
    // Effective per-shard admission quota: explicit when configured;
    // ADAPTIVE when the policy rejects but no quota was set — then
    // each routing decision derives the shard's quota from its
    // service-rate EWMA × the latency budget (usize::MAX until the
    // shard has served anything: an unmeasured shard must not shed).
    let fixed_quota =
        cfg.shard_quota.filter(|_| cfg.shed.rejects_over_quota());
    // Adaptive derivation is opt-in via the *pure* quota-rejection
    // policy only. `ShedExpired` without a quota keeps its documented
    // PR-2 meaning — deadline shedding with unlimited admission — and
    // must not silently start rejecting over a derived quota the user
    // never configured.
    let adaptive = cfg.shard_quota.is_none()
        && cfg.shed == ShedPolicy::RejectOverQuota;
    let budget_s = cfg.latency_budget.as_secs_f64();
    // Last derived quota surfaced per shard — the observability map in
    // the metrics is only written when the value CHANGES, not on every
    // routed request (the derivation itself is one EWMA read).
    let mut last_derived: HashMap<ShardKey, usize> = HashMap::new();
    // Online tuning: dispatcher-synthesized exploration jobs for
    // untuned buckets, capped at TUNE_QUOTA outstanding.
    let mut tune: Option<TuneCtx> = match (&store, cfg.online_tune) {
        (Some(s), true) => Some(TuneCtx {
            store: Arc::clone(s),
            inflight: Arc::new(Mutex::new(HashSet::new())),
            tuned: HashSet::new(),
        }),
        _ => None,
    };
    let mut front_open = true;

    while front_open || overflow_len > 0 {
        // 1. Flush overflows opportunistically (FIFO per shard).
        for (key, buf) in overflow.iter_mut() {
            let handle = shards.get(key).expect("overflow implies shard");
            while let Some(req) = buf.pop_front() {
                match handle.queue.try_push(req) {
                    Ok(()) => overflow_len -= 1,
                    Err(req) => {
                        buf.push_front(req);
                        break;
                    }
                }
            }
        }
        if !front_open {
            // Nothing new can arrive: drain remaining overflow with
            // blocking pushes (shard queues are still open — they close
            // below, after this loop).
            for (key, buf) in overflow.iter_mut() {
                let handle =
                    shards.get(key).expect("overflow implies shard");
                for req in buf.drain(..) {
                    overflow_len -= 1;
                    if let Err(req) = handle.queue.push_or_return(req) {
                        metrics.request_failed();
                        attach_err(&req.trace, &ServeError::Closed);
                        (req.reply)(Err(ServeError::Closed));
                    }
                }
            }
            break;
        }

        // 2. Take the next burst from the front queue. With overflow
        // pending we only poll briefly so stalled shards keep getting
        // flush attempts; otherwise we block until work or close.
        let burst = if overflow_len == 0 {
            let b = front.pop_batch(32);
            if b.is_empty() {
                front_open = false;
                continue;
            }
            b
        } else {
            match front.pop_batch_timeout(32, Duration::from_millis(1)) {
                Ok(b) => b, // possibly empty: timeout → retry flush
                Err(_closed) => {
                    front_open = false;
                    continue;
                }
            }
        };

        // 3. Route the burst, round-robining across sessions (fair
        // admission — one greedy session cannot fill a whole burst's
        // worth of shard-queue slots ahead of everyone else).
        for req in interleave_sessions(burst) {
            let key = req.item.shard_key();
            // Routing span: covers the admission decision — breaker
            // check, shard spawn, quota derivation — and ends at the
            // hand-off to the shard's line (or at the reject). Time
            // spent in the front queue before this point becomes the
            // synthesized `queue` span at commit.
            let mut route = req.trace.as_ref()
                .map(|t| t.span(SpanKind::Route));
            if let Some(g) = route.as_mut() {
                g.attr("shard", key.label());
            }
            // Circuit breaker: a quarantined artifact fails FAST at
            // routing time — no shard queue slot, no backend time —
            // with an explicit `Quarantined` reply. After the
            // cooldown, exactly one request per breaker passes as the
            // half-open probe; its execution outcome (recorded by the
            // shard worker) re-validates or re-opens.
            if let Some(q) = &quarantine {
                if let Some(qkey) = quarantine_key(&digests, &req.item) {
                    match q.admit(&qkey) {
                        Admission::Allow => {}
                        Admission::Probe => {
                            // half-open probe: mark the trace so an
                            // exemplar explains its own risk/latency
                            if let Some(g) = route.as_mut() {
                                g.attr("quarantine", "probe");
                            }
                        }
                        Admission::Deny => {
                            let artifact = match &req.item.payload {
                                WorkPayload::Artifact { id, .. } => {
                                    id.clone()
                                }
                                _ => qkey,
                            };
                            metrics.request_quarantined();
                            if !req.internal {
                                metrics.request_failed();
                            }
                            let err = ServeError::Quarantined {
                                artifact,
                            };
                            if let Some(g) = route.as_mut() {
                                g.attr("quarantine", "deny");
                                g.fail(&err);
                            }
                            drop(route);
                            (req.reply)(Err(err));
                            continue;
                        }
                    }
                }
            }
            // Online-tuning trigger: a request for an untuned
            // (dtype, bucket) seeds ONE bounded exploration job on the
            // tuner shard. Strictly non-blocking: over TUNE_QUOTA the
            // job is dropped and counted — serving traffic NEVER
            // waits on tuning.
            if let Some(tctx) = tune.as_mut() {
                if let Some((dtype, bucket)) =
                    tctx.wants_explore(&req.item)
                {
                    let tk = ShardKey::Tuner;
                    if !shards.contains_key(&tk) {
                        match spawn_shard(tk, &cfg, &native_src, &store,
                                          &disk, &park, &metrics,
                                          &cancel, &quarantine,
                                          &digests) {
                            Ok(handle) => {
                                // poisoned registry = shard invisible
                                // to depth reports, still serving (R2)
                                if let Ok(mut reg) = registry.lock() {
                                    reg.push((tk.label(),
                                              Arc::clone(&handle.queue)));
                                }
                                shards.insert(tk, handle);
                            }
                            Err(e) => {
                                eprintln!("[serve] cannot spawn tuning \
                                           shard: {e}");
                                tctx.abort(dtype, bucket);
                            }
                        }
                    }
                    if let Some(handle) = shards.get(&tk) {
                        let job = tctx.job(dtype, bucket, &metrics);
                        match handle.queue
                            .try_push_quota(job, TUNE_QUOTA)
                        {
                            Ok(()) => metrics.tune_job_enqueued(),
                            Err(PushRefusal::OverQuota(..))
                            | Err(PushRefusal::Full(_))
                            | Err(PushRefusal::Closed(_)) => {
                                // dropped, not queued elsewhere: the
                                // in-flight mark is released so a
                                // later request retries the bucket
                                metrics.tune_job_shed();
                                tctx.abort(dtype, bucket);
                            }
                        }
                    }
                }
            }
            if !shards.contains_key(&key) {
                match spawn_shard(key, &cfg, &native_src, &store, &disk,
                                  &park, &metrics, &cancel, &quarantine,
                                  &digests) {
                    Ok(handle) => {
                        if let Ok(mut reg) = registry.lock() {
                            reg.push((key.label(),
                                      Arc::clone(&handle.queue)));
                        }
                        shards.insert(key, handle);
                    }
                    Err(e) => {
                        if !req.internal {
                            metrics.request_failed();
                        }
                        let err = ServeError::Backend(
                            format!("{}: {e}", key.label()));
                        if let Some(g) = route.as_mut() {
                            g.fail(&err);
                        }
                        drop(route);
                        (req.reply)(Err(err));
                        continue;
                    }
                }
            }
            let handle = shards.get(&key).expect("just ensured");
            // Per-request effective quota (explicit, adaptive, or
            // unlimited — see above).
            let quota = match fixed_quota {
                Some(q) => q,
                None if adaptive => {
                    let q = metrics.derive_quota(&key.label(),
                                                 budget_s);
                    if last_derived.get(&key) != Some(&q) {
                        metrics.record_derived_quota(&key.label(), q);
                        last_derived.insert(key, q);
                    }
                    q
                }
                None => usize::MAX,
            };
            // Route decided: the span ends here, at the hand-off
            // attempt — shard-queue wait shows up as trace dead time
            // between `route` and the worker's first span.
            drop(route);
            let buf = overflow.entry(key).or_default();
            // Admission quota: the shard's outstanding line is its
            // queue PLUS its overflow buffer; with a rejecting policy
            // anything past the quota is shed HERE, explicitly, instead
            // of growing the line without bound. When the overflow
            // buffer is empty the queue enforces the quota itself
            // (try_push_quota); otherwise the combined queue+overflow
            // depth is checked manually below before joining the line.
            if buf.is_empty() {
                match handle.queue.try_push_quota(req, quota) {
                    Ok(()) => continue,
                    Err(PushRefusal::OverQuota(req, depth)) => {
                        metrics.request_shed();
                        let err = ServeError::Overloaded {
                            shard: key.label(),
                            depth,
                            quota,
                        };
                        attach_err(&req.trace, &err);
                        (req.reply)(Err(err));
                        continue;
                    }
                    Err(PushRefusal::Closed(req)) => {
                        // shard queues only close during shutdown,
                        // after this loop — defensive, never silent
                        metrics.request_failed();
                        attach_err(&req.trace, &ServeError::Closed);
                        (req.reply)(Err(ServeError::Closed));
                        continue;
                    }
                    Err(PushRefusal::Full(req)) => {
                        buf.push_back(req);
                        overflow_len += 1;
                    }
                }
            } else {
                let outstanding = handle.queue.len() + buf.len();
                if outstanding >= quota {
                    metrics.request_shed();
                    let err = ServeError::Overloaded {
                        shard: key.label(),
                        depth: outstanding,
                        quota,
                    };
                    attach_err(&req.trace, &err);
                    (req.reply)(Err(err));
                    continue;
                }
                // keep FIFO: never jump the shard's waiting line
                buf.push_back(req);
                overflow_len += 1;
            }
            // Memory bound: block on the saturated shard only.
            while overflow_len >= overflow_limit {
                let Some(req) = buf.pop_front() else { break };
                overflow_len -= 1;
                if let Err(req) = handle.queue.push_or_return(req) {
                    metrics.request_failed();
                    attach_err(&req.trace, &ServeError::Closed);
                    (req.reply)(Err(ServeError::Closed));
                }
            }
        }
    }

    for handle in shards.values() {
        handle.queue.close();
    }
    // Fold the per-queue high-water marks into the shared metrics now
    // that routing is over (cheaper than per-request observation).
    metrics.observe_front_depth(front.max_depth());
    for (_, handle) in shards.drain() {
        metrics.observe_shard_depth(handle.queue.max_depth());
        for w in handle.workers {
            let _ = w.join();
        }
    }
    // Workers are gone, so no further puts can race: drain the disk
    // cache's debounce window — a clean shutdown persists everything.
    if let Some(d) = &disk {
        d.flush();
    }
}

#[allow(clippy::too_many_arguments)]
fn spawn_shard(key: ShardKey, cfg: &ServeConfig,
               native_src: &Option<Arc<NativeSource>>,
               store: &Option<SharedTuningStore>,
               disk: &Option<Arc<SharedDiskCache>>,
               park: &Arc<MachinePark>, metrics: &Arc<ServeMetrics>,
               cancel: &Arc<AtomicBool>,
               quarantine: &Option<Arc<Quarantine>>,
               digests: &Arc<HashMap<String, String>>)
               -> Result<ShardHandle, String> {
    let queue: Arc<BoundedQueue<ServeRequest>> =
        Arc::new(BoundedQueue::new(cfg.shard_cap.max(1)));
    // The tuner shard never caches: a repeated exploration for the
    // same bucket must re-check the store, not replay a stale reply.
    let cache_cap = match key {
        ShardKey::Tuner => 0,
        _ => cfg.cache_cap,
    };
    let cache: Arc<Mutex<LruCache<Output>>> =
        Arc::new(Mutex::new(LruCache::new(cache_cap)));
    let threads = match key {
        ShardKey::Sim(_) => cfg.sim_threads.max(1),
        // Single shard worker per native engine: the PJRT client is
        // Rc-based (single-owner), and the threadpool backend
        // parallelizes inside itself. The tuner is single-worker by
        // design — concurrent explorations would contend for the very
        // cores they are timing.
        ShardKey::Native(_) | ShardKey::Tuner => 1,
    };
    let mut factories: Vec<BackendFactory> = Vec::new();
    match key {
        ShardKey::Sim(arch) => {
            for _ in 0..threads {
                let park = Arc::clone(park);
                factories.push(Box::new(move || {
                    Ok(Box::new(SimBackend::new(arch, &park))
                       as Box<dyn Backend>)
                }));
            }
        }
        ShardKey::Native(engine) => {
            // Both named native shards draw from the SAME shared
            // artifact source (Arc — `native:pjrt` and
            // `native:threadpool` read one copy of the manifest) and
            // the same tuning store (per-request kernel selection).
            let src = Arc::clone(native_src.as_ref().ok_or_else(|| {
                "no native backend configured (start the serve layer \
                 with ServeConfig::native set)".to_string()
            })?);
            let native_threads = cfg.native_threads;
            let store = store.clone();
            let plan = cfg.fault_plan.clone();
            // The factory is reusable (FnMut): worker supervision
            // respawns a panicked worker's backend from it, so the
            // captures are cloned per construction instead of moved.
            factories.push(Box::new(move || {
                let b: Box<dyn Backend> = match (engine, &*src) {
                    (NativeEngineId::Pjrt,
                     NativeSource::Manifest(m)) => {
                        // the PJRT backend owns its manifest (it keeps
                        // loading kernels from it) — one clone here
                        Box::new(NativeBackend::from_manifest(m.clone())
                                 .with_store(store.clone()))
                    }
                    (NativeEngineId::Pjrt,
                     NativeSource::Synthetic(ids)) => {
                        Box::new(NativeBackend::synthetic(ids)?
                                 .with_store(store.clone()))
                    }
                    (NativeEngineId::Threadpool,
                     NativeSource::Manifest(m)) => {
                        Box::new(ThreadpoolGemm::from_manifest(
                            m, native_threads)
                            .with_store(store.clone())
                            .with_fault(plan.clone()))
                    }
                    (NativeEngineId::Threadpool,
                     NativeSource::Synthetic(ids)) => {
                        Box::new(ThreadpoolGemm::synthetic(
                            ids, native_threads)?
                            .with_store(store.clone())
                            .with_fault(plan.clone()))
                    }
                };
                Ok(b)
            }));
        }
        ShardKey::Tuner => {
            let store = store.clone().ok_or_else(|| {
                "no tuning store configured (start the serve layer \
                 with ServeConfig::tuning_store or online_tune)"
                    .to_string()
            })?;
            let (budget, reps) = (cfg.tune_budget, cfg.tune_reps);
            // Exploration covers the threadpool fan-out axis sized to
            // the pool the threadpool shard actually runs.
            let fanout =
                crate::autotune::fanout_candidates(cfg.native_threads);
            factories.push(Box::new(move || {
                Ok(Box::new(TunerBackend::new(store.clone(), budget,
                                              reps)
                                .with_fanout(fanout.clone()))
                   as Box<dyn Backend>)
            }));
        }
    }
    let shed = cfg.shed;
    let quota = cfg.shard_quota.unwrap_or(0);
    // Only native shards carry the persistent result cache: sim
    // predictions are cheap to recompute and the tuner has its own
    // store — the disk tier exists to save native compute.
    let disk = match key {
        ShardKey::Native(_) => disk.clone(),
        ShardKey::Sim(_) | ShardKey::Tuner => None,
    };
    let workers = factories
        .into_iter()
        .enumerate()
        .map(|(widx, factory)| {
            let queue = Arc::clone(&queue);
            let cache = Arc::clone(&cache);
            let disk = disk.clone();
            let metrics = Arc::clone(metrics);
            let cancel = Arc::clone(cancel);
            let label = key.label();
            // The tuner serves strictly one exploration per dequeue:
            // draining a batch would defeat the outstanding-line
            // bound (TUNE_QUOTA counts QUEUED jobs — a batch pop
            // would sneak several into flight at once).
            let max_batch = match key {
                ShardKey::Tuner => 1,
                _ => cfg.max_batch.max(1),
            };
            let fault = ShardFaultCtx {
                plan: cfg.fault_plan.clone(),
                retry: cfg.retry,
                quarantine: quarantine.clone(),
                digests: Arc::clone(digests),
            };
            std::thread::Builder::new()
                .name(format!("serve-{}-{widx}", label.replace(':', "-")))
                .spawn(move || {
                    shard_loop(queue, factory, cache, disk, metrics,
                               cancel, max_batch, widx, label, shed,
                               quota, fault)
                })
                .expect("spawn shard worker")
        })
        .collect();
    Ok(ShardHandle { queue, workers })
}

/// Fold one *executed* native output into the per-shard compute
/// aggregate (cache hits never reach this — they do no compute).
fn observe_native_compute(metrics: &ServeMetrics, shard: &str,
                          output: &Output) {
    if let Output::Native { seconds, gflops: Some(g), .. } = output {
        metrics.observe_compute(shard, *seconds, *g);
    }
}

/// Steady-state service time of one executed request, for the adaptive
/// quota EWMA. Uses the output's own execution timing where one exists
/// — the wall time around `backend.run` includes one-off first-touch
/// work (input regeneration, the threadpool shard's sequential oracle
/// build, PJRT kernel loads) that can be 10–30× the steady-state cost
/// and would poison the EWMA into spurious shedding for many requests.
fn service_seconds(output: &Output, wall: f64) -> f64 {
    match output {
        Output::Sim { wall: w, .. } => *w,
        Output::Native { seconds, .. } => *seconds,
        Output::Tuned { .. } => wall,
    }
}

/// Per-worker fault context: the injection plan plus the recovery
/// policies (retry budget, quarantine breaker) and the digest map that
/// keys the breaker by artifact *content* rather than artifact id.
struct ShardFaultCtx {
    plan: Option<Arc<FaultPlan>>,
    retry: RetryPolicy,
    quarantine: Option<Arc<Quarantine>>,
    digests: Arc<HashMap<String, String>>,
}

/// Injected reply stall: fires after execution, before the replies go
/// out, so a stalled shard looks exactly like a slow backend to every
/// client-plane deadline. No lock is held across the sleep.
fn inject_stall(fault: &ShardFaultCtx,
                trace: Option<&Arc<ActiveTrace>>) {
    if let Some(p) = &fault.plan {
        if p.should_fire_traced(FaultSite::StallReply, trace) {
            std::thread::sleep(p.stall());
        }
    }
}

/// Span-attribute label of a pre-retry execution failure (the
/// post-retry [`ServeError`] mapping happens in `run_supervised`).
fn failure_variant(fail: &BackendFailure) -> &'static str {
    match fail {
        BackendFailure::Error(_) => "backend",
        BackendFailure::Corrupted { .. } => "corrupted",
    }
}

/// Retroactive `batch` span for one coalesced-group member: the wait
/// from group formation (dequeue) to the member's reply. Recorded at
/// reply time because detail members have no execution of their own —
/// the leader's single run answered them. Singleton groups skip it
/// (no coalescing happened, the span would be noise).
fn record_batch_span(req: &ServeRequest, t0: Option<u64>, size: usize) {
    if size <= 1 {
        return;
    }
    if let (Some(t), Some(start)) = (&req.trace, t0) {
        t.record(SpanKind::Batch, start,
                 vec![("size", size.to_string())]);
    }
}

/// Fold one *post-retry* execution outcome into the artifact circuit
/// breaker, surfacing the state transitions in metrics. Cache hits
/// count as successes too: a half-open probe answered from cache still
/// proves the artifact serveable and closes the breaker.
fn record_quarantine(fault: &ShardFaultCtx, metrics: &ServeMetrics,
                     item: &WorkItem, ok: bool) {
    let Some(q) = &fault.quarantine else { return };
    let Some(key) = quarantine_key(&fault.digests, item) else {
        return;
    };
    if ok {
        if q.record_success(&key) {
            metrics.quarantine_exit();
        }
    } else if q.record_failure(&key) {
        metrics.quarantine_enter();
    }
}

/// One shard worker's backend plus everything needed to heal it: the
/// reusable factory that respawns the backend after a panic, and a
/// private RNG for retry-backoff jitter (seeded from the fault-plan
/// seed so chaos runs replay their backoff schedule too).
struct WorkerState {
    backend: Option<Box<dyn Backend>>,
    factory: BackendFactory,
    label: String,
    rng: SplitMix64,
}

impl WorkerState {
    /// Run one item under supervision: injected faults, panic catch +
    /// respawn, and the budgeted retry policy. Returns the final
    /// outcome plus the number of attempts consumed (1 = first try).
    ///
    /// Retry applies ONLY to execution failures (`Backend` /
    /// `Corrupted`) — `Overloaded` and `Closed` are routing-time
    /// replies that never reach this function, so the policy cannot
    /// amplify overload.
    fn run_supervised(&mut self, item: &WorkItem,
                      fault: &ShardFaultCtx, metrics: &ServeMetrics,
                      trace: Option<&Arc<ActiveTrace>>)
                      -> (Result<Output, ServeError>, u32) {
        let budget = fault.retry.attempts();
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            // Injection happens *before* the backend runs so an
            // injected fault costs no compute. The tuner shard draws
            // from its own site, keeping tuner-commit failures tunable
            // independently of serving-path error rates.
            let site = if self.label.starts_with("tune:") {
                FaultSite::TunerCommit
            } else {
                FaultSite::BackendError
            };
            let injected = fault.plan.as_ref().and_then(|p| {
                p.should_fire(site).then(|| {
                    BackendFailure::Error(format!(
                        "{}: injected {}", self.label, site.label()))
                })
            });
            let result = match injected {
                Some(fail) => {
                    // The attempt never reached the backend: record a
                    // zero-compute execute span carrying the injected
                    // fault so the trace shows WHICH attempt died.
                    if let Some(t) = trace {
                        let mut g = t.span(SpanKind::Execute);
                        g.attr("shard", self.label.as_str());
                        g.attr("attempt", attempt.to_string());
                        g.fault(site);
                        g.end();
                    }
                    Err(fail)
                }
                None => {
                    self.run_caught(item, fault, metrics, trace,
                                    attempt)
                }
            };
            match result {
                Ok(out) => return (Ok(out), attempt),
                Err(fail) => {
                    if attempt < budget {
                        metrics.request_retried();
                        let unit = self.rng.next_unit();
                        let delay = fault.retry.delay(attempt + 1,
                                                      unit);
                        match trace {
                            Some(t) => {
                                // `retry#k` wraps the backoff sleep;
                                // attempt k+1's execute span follows,
                                // giving the … → retry#k → execute …
                                // shape the chaos exemplars show.
                                let mut g =
                                    t.span(SpanKind::Retry(attempt));
                                g.attr("error", failure_variant(&fail));
                                g.attr("delay_us",
                                       delay.as_micros().to_string());
                                let b = t.span(SpanKind::Backoff);
                                std::thread::sleep(delay);
                                b.end();
                                g.end();
                            }
                            None => std::thread::sleep(delay),
                        }
                        continue;
                    }
                    if budget > 1 {
                        metrics.retry_exhausted();
                    }
                    let err = match fail {
                        BackendFailure::Error(m) => {
                            ServeError::Backend(m)
                        }
                        BackendFailure::Corrupted { artifact, .. } => {
                            metrics.request_corrupted();
                            ServeError::Corrupted {
                                shard: self.label.clone(),
                                artifact,
                            }
                        }
                    };
                    if let Some(t) = trace {
                        t.attach("error", trace::error_variant(&err));
                    }
                    return (Err(err), attempt);
                }
            }
        }
    }

    /// One attempt: catch a panicking backend (injected or organic),
    /// count the restart and rebuild from the factory so the *next*
    /// attempt — and every later request — still has a live backend.
    /// The in-flight item's reply is preserved: a panic surfaces as an
    /// ordinary `BackendFailure`, never a dropped reply channel.
    fn run_caught(&mut self, item: &WorkItem, fault: &ShardFaultCtx,
                  metrics: &ServeMetrics,
                  trace: Option<&Arc<ActiveTrace>>, attempt: u32)
                  -> Result<Output, BackendFailure> {
        let panic_fuse = fault.plan.as_ref()
            .is_some_and(|p| p.should_fire(FaultSite::WorkerPanic));
        if self.backend.is_none() {
            match (self.factory)() {
                Ok(b) => self.backend = Some(b),
                Err(e) => {
                    return Err(BackendFailure::Error(format!(
                        "{}: backend respawn failed: {e}",
                        self.label)));
                }
            }
        }
        let backend = self.backend.as_mut().expect("installed above");
        // The execute span brackets the whole attempt — including a
        // panicking one (the guard records on drop, catch_unwind or
        // not) and post-panic respawn time, which IS part of what the
        // attempt cost this request.
        let mut exec = trace.map(|t| t.span(SpanKind::Execute));
        if let Some(g) = exec.as_mut() {
            g.attr("shard", self.label.as_str());
            g.attr("attempt", attempt.to_string());
        }
        let run = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                if panic_fuse {
                    panic!("{}: injected worker panic", self.label);
                }
                backend.run_traced(item, trace)
            }));
        match run {
            Ok(result) => {
                if let (Some(g), Err(fail)) = (exec.as_mut(), &result) {
                    g.attr("error", failure_variant(fail));
                }
                result
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                metrics.worker_restarted();
                if let Some(g) = exec.as_mut() {
                    if panic_fuse {
                        g.fault(FaultSite::WorkerPanic);
                    }
                    g.attr("error", "panic");
                }
                // Respawn eagerly so the shard keeps serving even when
                // the caller is out of retry budget.
                self.backend = match (self.factory)() {
                    Ok(b) => Some(b),
                    Err(e) => {
                        eprintln!("[serve] {}: backend respawn failed \
                                   after panic: {e}", self.label);
                        None
                    }
                };
                Err(BackendFailure::Error(format!(
                    "{}: worker panicked: {msg} (backend respawned)",
                    self.label)))
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_loop(queue: Arc<BoundedQueue<ServeRequest>>,
              mut factory: BackendFactory,
              cache: Arc<Mutex<LruCache<Output>>>,
              disk: Option<Arc<SharedDiskCache>>,
              metrics: Arc<ServeMetrics>, cancel: Arc<AtomicBool>,
              max_batch: usize, worker: usize, label: String,
              shed: ShedPolicy, quota: usize, fault: ShardFaultCtx) {
    let backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            // Init failed: every request — queued now or later — gets an
            // explicit error until the queue closes.
            loop {
                let batch = queue.pop_batch(max_batch);
                if batch.is_empty() {
                    return;
                }
                for req in batch {
                    if !req.internal {
                        metrics.request_failed();
                    }
                    let err = ServeError::Backend(
                        format!("{label}: backend init failed: {e}"));
                    attach_err(&req.trace, &err);
                    (req.reply)(Err(err));
                }
            }
        }
    };
    // Jitter stream: deterministic per (plan seed, shard, worker) so a
    // chaos run's backoff schedule replays from the same seed.
    let rng_seed = fault.plan.as_ref().map_or(0, |p| p.seed())
        ^ seed_for(&label, worker as u64);
    let mut state = WorkerState {
        backend: Some(backend),
        factory,
        label: label.clone(),
        rng: SplitMix64::new(rng_seed),
    };
    loop {
        let mut batch = queue.pop_batch(max_batch);
        if batch.is_empty() {
            return; // closed and drained
        }
        // Deadline shedding at dequeue: executing an already-expired
        // request wastes backend time that live requests behind it
        // need — shed it with an explicit Overloaded reply instead.
        if shed.sheds_expired() {
            let now = Instant::now();
            let depth = queue.len();
            let mut live = Vec::with_capacity(batch.len());
            for req in batch {
                if req.item.expired(now) {
                    metrics.request_shed();
                    let err = ServeError::Overloaded {
                        shard: label.clone(),
                        depth,
                        quota,
                    };
                    attach_err(&req.trace, &err);
                    (req.reply)(Err(err));
                } else {
                    live.push(req);
                }
            }
            batch = live;
            if batch.is_empty() {
                continue;
            }
        }
        // Continuous batching: group the drained requests by work key
        // (first-appearance order) and serve each group with ONE
        // backend execution.
        let mut order: Vec<String> = Vec::new();
        let mut groups: HashMap<String, Vec<ServeRequest>> =
            HashMap::new();
        for req in batch {
            let key = req.item.cache_key();
            groups.entry(key.clone()).or_insert_with(|| {
                order.push(key);
                Vec::new()
            }).push(req);
        }
        for key in order {
            let group = groups.remove(&key).expect("grouped above");
            let batch_size = group.len();
            metrics.observe_batch(batch_size);
            // Coalesced-wait starts: each member's `batch` span is
            // recorded retroactively at its reply (only groups > 1).
            let batch_t0: Vec<Option<u64>> = if batch_size > 1 {
                group.iter()
                    .map(|r| r.trace.as_ref().map(|t| t.now_us()))
                    .collect()
            } else {
                Vec::new()
            };

            if cancel.load(Ordering::SeqCst) {
                for req in group {
                    if !req.internal {
                        metrics.request_cancelled();
                    }
                    attach_err(&req.trace, &ServeError::Cancelled);
                    (req.reply)(Err(ServeError::Cancelled));
                }
                continue;
            }

            // a poisoned result cache degrades to miss-and-disabled:
            // requests recompute instead of panicking the shard (R2)
            let probe_t0 =
                group[0].trace.as_ref().map(|t| t.now_us());
            let (cached, cache_enabled) = match cache.lock() {
                Ok(mut c) => (c.get(&key), c.enabled()),
                Err(_) => (None, false),
            };
            // Leader-recorded probe span (detail members share the
            // outcome; their own traces show it via `cache` on the
            // committed record).
            if let (Some(t), Some(start), true) =
                (&group[0].trace, probe_t0, cache_enabled)
            {
                t.record(SpanKind::CacheMem, start,
                         vec![("hit", cached.is_some().to_string())]);
            }
            // Pre-serve wait snapshot: `queue_seconds` means "wait from
            // submission until this shard started serving the item" on
            // EVERY path — the cache-hit path must not report reply-loop
            // time (or an earlier group member's slow reply callback) as
            // queue wait. The measurement path (cache disabled) times
            // each request immediately before its own execution instead,
            // so it skips this allocation entirely.
            let waits: Vec<f64> = if cache_enabled {
                group.iter()
                    .map(|r| r.enqueued.elapsed().as_secs_f64())
                    .collect()
            } else {
                Vec::new()
            };
            if let Some(output) = cached {
                metrics.cache_hit(batch_size as u64);
                record_quarantine(&fault, &metrics, &group[0].item,
                                  true);
                for (i, (req, wait)) in
                    group.into_iter().zip(waits).enumerate()
                {
                    record_batch_span(&req,
                                      batch_t0.get(i).copied()
                                          .flatten(),
                                      batch_size);
                    let latency = req.enqueued.elapsed().as_secs_f64();
                    if !req.internal {
                        metrics.request_completed(latency);
                    }
                    (req.reply)(Ok(ServeReply {
                        shard: label.clone(),
                        output: output.clone(),
                        batch_size,
                        queue_seconds: wait,
                        cache_hit: true,
                        cache_src: CacheSource::Mem,
                        worker,
                        attempts: 1,
                    }));
                }
                continue;
            }
            // Memory miss → probe the persistent tier (native shards
            // with a result_cache_path only). A disk hit seeds the LRU
            // so the next repeat is a memory hit, and replies carry
            // `cache:disk` so the tier split is attributable.
            if cache_enabled && disk.is_some() {
                let probe_t0 =
                    group[0].trace.as_ref().map(|t| t.now_us());
                let probed = disk.as_ref().and_then(|d| {
                    d.get(&label, &key, group[0].trace.as_ref())
                });
                if let (Some(t), Some(start)) =
                    (&group[0].trace, probe_t0)
                {
                    t.record(SpanKind::CacheDisk, start,
                             vec![("hit",
                                   probed.is_some().to_string())]);
                }
                if let Some(output) = probed {
                    metrics.cache_hit_disk(batch_size as u64);
                    record_quarantine(&fault, &metrics, &group[0].item,
                                      true);
                    if let Ok(mut c) = cache.lock() {
                        c.put(key, output.clone());
                    }
                    for (i, (req, wait)) in
                        group.into_iter().zip(waits).enumerate()
                    {
                        record_batch_span(&req,
                                          batch_t0.get(i).copied()
                                              .flatten(),
                                          batch_size);
                        let latency =
                            req.enqueued.elapsed().as_secs_f64();
                        if !req.internal {
                            metrics.request_completed(latency);
                        }
                        (req.reply)(Ok(ServeReply {
                            shard: label.clone(),
                            output: output.clone(),
                            batch_size,
                            queue_seconds: wait,
                            cache_hit: true,
                            cache_src: CacheSource::Disk,
                            worker,
                            attempts: 1,
                        }));
                    }
                    continue;
                }
            }
            if cache_enabled {
                // Serving semantics: equal work keys are interchangeable
                // — ONE execution answers the whole group and seeds the
                // cache.
                metrics.cache_miss(batch_size as u64);
                let t_exec = Instant::now();
                let (result, attempts) = state.run_supervised(
                    &group[0].item, &fault, &metrics,
                    group[0].trace.as_ref());
                match result {
                    Ok(output) => {
                        record_quarantine(&fault, &metrics,
                                          &group[0].item, true);
                        if !group[0].internal {
                            metrics.observe_service(
                                &label,
                                service_seconds(
                                    &output,
                                    t_exec.elapsed().as_secs_f64()));
                        }
                        observe_native_compute(&metrics, &label,
                                               &output);
                        // spill-through: the persistent tier records
                        // every executed native result (debounced
                        // atomic write outside the lookup lock)
                        if let Some(d) = &disk {
                            let evicted =
                                d.put(&label, &key, &output,
                                      group[0].trace.as_ref());
                            if evicted > 0 {
                                metrics.cache_evict_disk(evicted);
                            }
                        }
                        if let Ok(mut c) = cache.lock() {
                            c.put(key, output.clone());
                        }
                        inject_stall(&fault, group[0].trace.as_ref());
                        for (i, (req, wait)) in
                            group.into_iter().zip(waits).enumerate()
                        {
                            record_batch_span(&req,
                                              batch_t0.get(i).copied()
                                                  .flatten(),
                                              batch_size);
                            let latency =
                                req.enqueued.elapsed().as_secs_f64();
                            if !req.internal {
                                metrics.request_completed(latency);
                            }
                            (req.reply)(Ok(ServeReply {
                                shard: label.clone(),
                                output: output.clone(),
                                batch_size,
                                queue_seconds: wait,
                                cache_hit: false,
                                cache_src: CacheSource::Miss,
                                worker,
                                attempts,
                            }));
                        }
                    }
                    Err(err) => {
                        record_quarantine(&fault, &metrics,
                                          &group[0].item, false);
                        inject_stall(&fault, group[0].trace.as_ref());
                        for (i, req) in group.into_iter().enumerate() {
                            record_batch_span(&req,
                                              batch_t0.get(i).copied()
                                                  .flatten(),
                                              batch_size);
                            if !req.internal {
                                metrics.request_failed();
                            }
                            if i > 0 {
                                // the leader's trace already carries
                                // the error from run_supervised
                                attach_err(&req.trace, &err);
                            }
                            (req.reply)(Err(err.clone()));
                        }
                    }
                }
            } else {
                // Measurement semantics (cache disabled — the Scheduler
                // and GemmService shims): EVERY request executes, so
                // per-request timings are real observations, never a
                // duplicated clone. Batching still amortises queue
                // churn and is reported via batch_size.
                for req in group {
                    let wait = req.enqueued.elapsed().as_secs_f64();
                    let t_exec = Instant::now();
                    let (result, attempts) = state.run_supervised(
                        &req.item, &fault, &metrics,
                        req.trace.as_ref());
                    match result {
                        Ok(output) => {
                            record_quarantine(&fault, &metrics,
                                              &req.item, true);
                            if !req.internal {
                                metrics.observe_service(
                                    &label,
                                    service_seconds(
                                        &output,
                                        t_exec.elapsed()
                                            .as_secs_f64()));
                            }
                            observe_native_compute(&metrics, &label,
                                                   &output);
                            let latency =
                                req.enqueued.elapsed().as_secs_f64();
                            if !req.internal {
                                metrics.request_completed(latency);
                            }
                            inject_stall(&fault, req.trace.as_ref());
                            (req.reply)(Ok(ServeReply {
                                shard: label.clone(),
                                output,
                                batch_size,
                                queue_seconds: wait,
                                cache_hit: false,
                                cache_src: CacheSource::Miss,
                                worker,
                                attempts,
                            }));
                        }
                        Err(err) => {
                            record_quarantine(&fault, &metrics,
                                              &req.item, false);
                            inject_stall(&fault, req.trace.as_ref());
                            if !req.internal {
                                metrics.request_failed();
                            }
                            (req.reply)(Err(err));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;
    use crate::sim::TuningPoint;

    fn knl_point(t: u64) -> WorkItem {
        WorkItem::point(TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                         Precision::F64, 1024, t, 1))
    }

    #[test]
    fn sim_call_roundtrip() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let reply = serve.call(knl_point(64)).unwrap();
        assert_eq!(reply.shard, "sim:knl");
        assert!(!reply.cache_hit);
        match reply.output {
            Output::Sim { record, .. } => assert!(record.gflops > 0.0),
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn cache_hits_on_repeat() {
        let cfg = ServeConfig { cache_cap: 16, ..Default::default() };
        let serve = Serve::start(cfg).unwrap();
        let first = serve.call(knl_point(32)).unwrap();
        assert!(!first.cache_hit);
        let second = serve.call(knl_point(32)).unwrap();
        assert!(second.cache_hit);
        assert!(serve.metrics.cache_hits() >= 1);
        assert!(serve.metrics.cache_hit_rate() > 0.0);
        serve.shutdown();
    }

    #[test]
    fn submit_after_close_gets_explicit_error() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        serve.close();
        let rx = serve.submit(knl_point(16));
        assert!(matches!(rx.recv().unwrap(), Err(ServeError::Closed)));
        assert!(matches!(serve.try_submit(knl_point(16)),
                         Err(ServeError::Closed)));
        serve.shutdown();
    }

    #[test]
    fn cancel_replies_cancelled_not_silence() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        serve.cancel();
        let rx = serve.submit(knl_point(64));
        match rx.recv().unwrap() {
            Err(ServeError::Cancelled) | Ok(_) => {} // race with dispatch
            other => panic!("unexpected {other:?}"),
        }
        assert!(serve.cancelled());
        serve.shutdown();
    }

    #[test]
    fn native_unconfigured_is_explicit_backend_error() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let err = serve
            .call(WorkItem::artifact("dot_n64_f32"))
            .unwrap_err();
        match err {
            ServeError::Backend(m) => {
                assert!(m.contains("no native backend"), "{m}");
            }
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn synthetic_native_shard_serves() {
        let cfg = ServeConfig {
            cache_cap: 8,
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n64_f32".to_string(),
            ])),
            ..Default::default()
        };
        let serve = Serve::start(cfg).unwrap();
        let r = serve.call(WorkItem::artifact("dot_n64_f32"))
            .unwrap();
        assert_eq!(r.shard, "native:pjrt");
        match r.output {
            Output::Native { seconds, engine, .. } => {
                assert!(seconds > 0.0);
                assert_eq!(engine, NativeEngine::HostGemm);
            }
            other => panic!("unexpected {other:?}"),
        }
        let again = serve.call(WorkItem::artifact("dot_n64_f32"))
            .unwrap();
        assert!(again.cache_hit);
        // the same artifact on the NAMED second native shard: computed
        // by the threadpool GEMM, oracle-checked inside the backend
        let tp = serve.call(WorkItem::artifact_on(
            "dot_n64_f32", NativeEngineId::Threadpool)).unwrap();
        assert_eq!(tp.shard, "native:threadpool");
        match tp.output {
            Output::Native { engine, .. } => {
                assert_eq!(engine, NativeEngine::ThreadpoolGemm);
            }
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn bad_synthetic_ids_rejected_at_start() {
        let cfg = ServeConfig {
            native: Some(NativeConfig::Synthetic(vec![
                "mlp_b32_f32".to_string(),
            ])),
            ..Default::default()
        };
        assert!(Serve::start(cfg).is_err());
    }

    #[test]
    fn quota_rejection_is_explicit_and_counted() {
        // quota 0 = every request shed: fully deterministic
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::RejectOverQuota,
            shard_quota: Some(0),
            ..Default::default()
        }).unwrap();
        let err = serve.call(knl_point(32)).unwrap_err();
        match err {
            ServeError::Overloaded { shard, depth, quota } => {
                assert_eq!(shard, "sim:knl");
                assert_eq!(depth, 0);
                assert_eq!(quota, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(serve.metrics.shed(), 1);
        assert!(serve.metrics.shed_rate() > 0.0);
        assert!(serve.summary().contains("1 shed"));
        serve.shutdown();
    }

    #[test]
    fn quota_ignored_without_a_rejecting_policy() {
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::None,
            shard_quota: Some(0),
            ..Default::default()
        }).unwrap();
        assert!(serve.call(knl_point(32)).is_ok(),
                "policy None must never shed");
        assert_eq!(serve.metrics.shed(), 0);
        serve.shutdown();
    }

    #[test]
    fn expired_deadline_is_shed_at_dequeue() {
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::ShedExpired,
            ..Default::default()
        }).unwrap();
        // deadline = submission instant: expired by dequeue time
        let item = knl_point(64).with_deadline(Instant::now());
        match serve.call(item).unwrap_err() {
            ServeError::Overloaded { shard, quota, .. } => {
                assert_eq!(shard, "sim:knl");
                assert_eq!(quota, 0, "no quota configured");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(serve.metrics.shed(), 1);
        // a live deadline sails through
        let ok = serve.call(knl_point(64).with_deadline_in(
            std::time::Duration::from_secs(3600)));
        assert!(ok.is_ok());
        serve.shutdown();
    }

    #[test]
    fn deadlines_inert_without_expiry_policy() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let item = knl_point(16).with_deadline(Instant::now());
        assert!(serve.call(item).is_ok(),
                "ShedPolicy::None must ignore deadlines");
        serve.shutdown();
    }

    #[test]
    fn live_summary_sees_shard_depths_mid_run() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        for t in [16u64, 32, 64] {
            serve.call(knl_point(t)).unwrap();
        }
        // Mid-run (NOT shutdown): the registry walk must surface the
        // shard queue's high-water mark; requests flowed through the
        // queue, so it is at least 1.
        assert!(serve.metrics.shard_depth_high_water() <= 1,
                "precondition: nothing folded before summary()");
        let _ = serve.summary();
        assert!(serve.metrics.shard_depth_high_water() >= 1,
                "live summary must fold shard depths");
        let depths = serve.shard_depths();
        assert_eq!(depths.len(), 1);
        assert_eq!(depths[0].0, "sim:knl");
        assert!(depths[0].2 >= 1, "high-water from live registry");
        serve.shutdown();
    }

    #[test]
    fn cache_hit_queue_seconds_is_pre_serve_wait_not_reply_time() {
        // Regression for the queue_seconds semantics bug: the cache-hit
        // path used to report full end-to-end latency (measured at
        // reply time, AFTER earlier group members' reply callbacks ran)
        // as the queue wait. Slow reply callbacks of earlier group
        // members must not inflate later members' queue_seconds.
        use std::sync::mpsc::channel;
        let serve = Serve::start(ServeConfig {
            cache_cap: 16,
            max_batch: 8,
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n64_f32".to_string(),
                "gemm_n512_t16_e1_f32".to_string(),
            ])),
            ..Default::default()
        }).unwrap();
        // warm the cache for the small artifact
        serve.call(WorkItem::artifact("dot_n64_f32")).unwrap();
        // Occupy the single pjrt shard worker with slow work (n=512
        // host GEMM, ≫ 20ms); give the worker a moment to dequeue it
        // ALONE, then queue three hits behind it so they coalesce into
        // one later batch.
        let slow = serve.submit(
            WorkItem::artifact("gemm_n512_t16_e1_f32"));
        std::thread::sleep(std::time::Duration::from_millis(5));
        let (tx, rx) = channel();
        for i in 0..3 {
            let tx = tx.clone();
            serve.submit_with(
                WorkItem::artifact("dot_n64_f32"),
                Box::new(move |r| {
                    if i == 0 {
                        // a deliberately slow reply callback
                        std::thread::sleep(
                            std::time::Duration::from_millis(80));
                    }
                    let _ = tx.send((i, r));
                }));
        }
        drop(tx);
        let mut replies: Vec<_> = rx.iter().collect();
        replies.sort_by_key(|(i, _)| *i);
        assert_eq!(replies.len(), 3);
        let waits: Vec<f64> = replies
            .iter()
            .map(|(_, r)| r.as_ref().unwrap().queue_seconds)
            .collect();
        // All three were served from cache in ONE group, so their
        // pre-serve waits differ only by their sub-millisecond submit
        // spacing. Member 0's 80ms reply callback must NOT appear in
        // members 1 and 2's queue wait (the old code measured at reply
        // time, after that callback).
        for (i, w) in waits.iter().enumerate().skip(1) {
            assert!(*w <= waits[0] + 0.060,
                    "hit member {i} queue_seconds {w}s vs member 0 \
                     {}s: includes reply time of earlier members",
                    waits[0]);
        }
        let _ = slow.recv().unwrap().unwrap();
        serve.shutdown();
    }

    #[test]
    fn user_submitted_explore_runs_on_the_tuner_shard() {
        // Explicit warm-up path: a submitted Explore item routes to
        // tune:explore, commits to the layer's store, and counts as a
        // normal (user-facing) completed request.
        let serve = Serve::start(ServeConfig {
            online_tune: true,
            tune_budget: 2,
            tune_reps: 1,
            ..Default::default()
        }).unwrap();
        let r = serve.call(WorkItem::explore(Precision::F64, 32))
            .unwrap();
        assert_eq!(r.shard, "tune:explore");
        match r.output {
            Output::Tuned { committed, bucket, .. } => {
                assert!(committed);
                assert_eq!(bucket, 32);
            }
            other => panic!("unexpected {other:?}"),
        }
        let store = serve.tuning_store().expect("online store");
        assert!(store.lock().unwrap()
                .lookup(Precision::F64, 32).is_some());
        serve.shutdown();
    }

    #[test]
    fn explore_without_store_is_an_explicit_error() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let err = serve.call(WorkItem::explore(Precision::F32, 64))
            .unwrap_err();
        match err {
            ServeError::Backend(m) => {
                assert!(m.contains("no tuning store"), "{m}");
            }
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn adaptive_quota_derives_and_surfaces_after_service() {
        // Rejecting policy + no explicit quota = adaptive. A generous
        // budget means nothing sheds in a sequential closed loop, but
        // after the first completion the derived quota must appear in
        // the summary.
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::RejectOverQuota,
            shard_quota: None,
            latency_budget: std::time::Duration::from_secs(30),
            ..Default::default()
        }).unwrap();
        for t in [16u64, 32, 64, 16, 32] {
            serve.call(knl_point(t)).unwrap();
        }
        assert_eq!(serve.metrics.shed(), 0,
                   "sequential traffic under a huge budget never sheds");
        assert!(serve.metrics.service_ewma("sim:knl").is_some());
        let quotas = serve.metrics.derived_quotas();
        assert!(quotas.iter().any(|(l, q)| l == "sim:knl" && *q >= 1),
                "{quotas:?}");
        assert!(serve.summary().contains("adaptive quota"), "{}",
                serve.summary());
        serve.shutdown();
    }

    #[test]
    fn shed_expired_without_quota_never_derives_adaptive_quotas() {
        // PR-2 semantics preserved: ShedExpired + no quota = deadline
        // shedding with UNLIMITED admission. Even with a latency
        // budget that would derive quota 1, nothing may shed and
        // nothing may be derived.
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::ShedExpired,
            shard_quota: None,
            latency_budget: std::time::Duration::from_nanos(1),
            ..Default::default()
        }).unwrap();
        for t in [16u64, 32, 64, 16, 32, 64] {
            serve.call(knl_point(t)).unwrap();
        }
        assert_eq!(serve.metrics.shed(), 0,
                   "no deadlines set, so nothing may shed");
        assert!(serve.metrics.derived_quotas().is_empty(),
                "adaptive derivation is RejectOverQuota-only");
        serve.shutdown();
    }

    #[test]
    fn explicit_quota_still_wins_over_adaptive_path() {
        // shard_quota Some(0) + rejecting policy: everything sheds,
        // exactly as before the adaptive path existed.
        let serve = Serve::start(ServeConfig {
            shed: ShedPolicy::RejectOverQuota,
            shard_quota: Some(0),
            ..Default::default()
        }).unwrap();
        assert!(matches!(serve.call(knl_point(16)),
                         Err(ServeError::Overloaded { .. })));
        assert!(serve.metrics.derived_quotas().is_empty(),
                "explicit quota must not derive anything");
        serve.shutdown();
    }

    #[test]
    fn interleave_round_robins_sessions_preserving_lane_fifo() {
        let req = |session: Option<u64>, t: u64| ServeRequest {
            item: match session {
                Some(s) => knl_point(t).with_session(s),
                None => knl_point(t),
            },
            reply: Box::new(|_| {}),
            enqueued: Instant::now(),
            internal: false,
            trace: None,
        };
        // greedy session 1 (4 requests), session 2 (2), untagged (1)
        let burst = vec![req(Some(1), 16), req(Some(1), 32),
                         req(Some(1), 64), req(Some(2), 16),
                         req(None, 32), req(Some(1), 16),
                         req(Some(2), 32)];
        let out = interleave_sessions(burst);
        let tags: Vec<Option<u64>> =
            out.iter().map(|r| r.item.session).collect();
        assert_eq!(tags, vec![Some(1), Some(2), None, Some(1), Some(2),
                              Some(1), Some(1)],
                   "round-robin across lanes in first-appearance order");
        // per-lane FIFO: session 2's t values arrive 16 then 32
        let s2: Vec<u64> = out.iter()
            .filter(|r| r.item.session == Some(2))
            .map(|r| match &r.item.payload {
                WorkPayload::Point(p) => p.t,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(s2, vec![16, 32]);
        // single-lane bursts come back untouched
        let single = interleave_sessions(vec![req(None, 16),
                                              req(None, 32)]);
        assert_eq!(single.len(), 2);
        assert!(interleave_sessions(Vec::new()).is_empty());
    }

    #[test]
    fn disk_result_cache_survives_restart_and_labels_tiers() {
        let dir = std::env::temp_dir().join("alpaka-serve-diskcache");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("serve_result_cache.json");
        let _ = std::fs::remove_file(&path);
        let cfg = || ServeConfig {
            cache_cap: 16,
            result_cache_path: Some(path.clone()),
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n64_f32".to_string(),
            ])),
            ..Default::default()
        };
        {
            let serve = Serve::start(cfg()).unwrap();
            let first = serve.call(WorkItem::artifact("dot_n64_f32"))
                .unwrap();
            assert_eq!(first.cache_src, CacheSource::Miss);
            assert!(!first.cache_hit);
            // repeat in-process: memory tier answers
            let again = serve.call(WorkItem::artifact("dot_n64_f32"))
                .unwrap();
            assert_eq!(again.cache_src, CacheSource::Mem);
            assert_eq!(again.cache_src.label(), "cache:mem");
            assert!(again.cache_hit);
            assert_eq!(serve.metrics.cache_hits_disk(), 0);
            serve.shutdown();
        }
        assert!(path.exists(), "executed result spilled to disk");
        {
            // RESTART: memory LRU is cold, the disk tier answers the
            // first request without executing anything
            let serve = Serve::start(cfg()).unwrap();
            let r = serve.call(WorkItem::artifact("dot_n64_f32"))
                .unwrap();
            assert!(r.cache_hit);
            assert_eq!(r.cache_src, CacheSource::Disk);
            assert_eq!(r.cache_src.label(), "cache:disk");
            assert_eq!(serve.metrics.cache_hits_disk(), 1);
            // the disk hit seeded the LRU: next repeat is a memory hit
            let again = serve.call(WorkItem::artifact("dot_n64_f32"))
                .unwrap();
            assert_eq!(again.cache_src, CacheSource::Mem);
            assert!(serve.summary().contains("Hd"), "{}",
                    serve.summary());
            // the SAME artifact on the OTHER named engine must MISS:
            // disk entries are namespaced per shard, so a pjrt result
            // can never replay to a threadpool request (which must run
            // its own oracle-checked execution)
            let tp = serve.call(WorkItem::artifact_on(
                "dot_n64_f32", NativeEngineId::Threadpool)).unwrap();
            assert_eq!(tp.cache_src, CacheSource::Miss);
            match tp.output {
                Output::Native { engine, .. } => {
                    assert_eq!(engine, NativeEngine::ThreadpoolGemm);
                }
                other => panic!("unexpected {other:?}"),
            }
            serve.shutdown();
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn result_cache_path_inert_under_measurement_semantics() {
        let dir = std::env::temp_dir().join("alpaka-serve-diskcache");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("measurement_no_spill.json");
        let _ = std::fs::remove_file(&path);
        let serve = Serve::start(ServeConfig {
            cache_cap: 0, // measurement semantics: every request runs
            result_cache_path: Some(path.clone()),
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n32_f32".to_string(),
            ])),
            ..Default::default()
        }).unwrap();
        for _ in 0..2 {
            let r = serve.call(WorkItem::artifact("dot_n32_f32"))
                .unwrap();
            assert!(!r.cache_hit);
            assert_eq!(r.cache_src, CacheSource::Miss);
        }
        serve.shutdown();
        assert!(!path.exists(),
                "measurement-semantics layers must not spill");
    }

    #[test]
    fn submit_handle_resolves_and_dropping_is_clean() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        // resolve after wait
        let h = serve.submit_handle(knl_point(32));
        let reply = h.recv().unwrap();
        assert_eq!(reply.shard, "sim:knl");
        // poll-style
        let mut h = serve.submit_handle(knl_point(16));
        let r = loop {
            if let Some(r) = h.poll() {
                break r;
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        assert!(r.is_ok());
        // dropping a pending handle neither hangs shutdown nor panics
        // the replying worker
        let pending = serve.submit_handle(knl_point(64));
        drop(pending);
        serve.shutdown();
    }

    #[test]
    fn shutdown_drains_all_pending_requests() {
        let serve = Serve::start(ServeConfig {
            front_cap: 64,
            ..Default::default()
        }).unwrap();
        let rxs: Vec<_> = (0..24)
            .map(|i| serve.submit(knl_point([16, 32, 64][i % 3])))
            .collect();
        serve.shutdown(); // must drain, not drop
        let mut ok = 0;
        for rx in rxs {
            match rx.recv().expect("explicit reply even after shutdown") {
                Ok(_) => ok += 1,
                Err(e) => panic!("pre-shutdown request failed: {e}"),
            }
        }
        assert_eq!(ok, 24, "zero silent drops on shutdown");
    }
}
