//! The unified serve layer — ONE admission-controlled front queue, ONE
//! dispatcher, per-backend **shards**, cross-request **batching**, an
//! LRU **result cache** and unified **metrics**, shared by everything
//! that executes work in this repo.
//!
//! Before this module existed the repo had two disjoint concurrency
//! stacks: `coordinator::Scheduler` (sweep jobs over simulated
//! machines) and `runtime::GemmService` (PJRT artifact serving), each
//! with its own queue, worker loop and counters. The paper's own thesis
//! — one implementation, tuned per backend — applies to the serving
//! plane too, so both are now thin shims over this layer.
//!
//! # Architecture
//!
//! ```text
//!  clients ──submit──▶ front BoundedQueue (admission control)
//!                          │ dispatcher thread
//!            ┌─────────────┼──────────────┬──────────────┐
//!            ▼             ▼              ▼              ▼
//!      shard sim:knl  shard sim:p100  shard sim:…   shard native
//!      (N threads)    (N threads)     (N threads)   (1 thread — the
//!            │             │              │          PJRT client is
//!            ▼             ▼              ▼          Rc-based)
//!       pop_batch → group by work key → LRU cache → Backend::run
//!                          │
//!                          └──▶ reply channels + ServeMetrics
//! ```
//!
//! * **Admission**: `submit` blocks while the front queue is full
//!   (backpressure) and fails *explicitly* with [`ServeError::Closed`]
//!   after shutdown — a request is never silently dropped.
//! * **Shards**: created lazily by the dispatcher, one per simulated
//!   [`ArchId`](crate::arch::ArchId) plus a single-owner native shard.
//! * **Batching**: shard workers drain up to `max_batch` requests in one
//!   `pop_batch`, group them by work key, and serve each group with one
//!   backend execution.
//! * **Caching**: per-shard LRU keyed by the canonical work-item key;
//!   disabled (capacity 0) for measurement-oriented callers.
//! * **Shutdown**: `close` stops admission; queued work is drained,
//!   executed and replied to before workers exit. `cancel` short-cuts
//!   execution but still replies ([`ServeError::Cancelled`]).

pub mod backend;
pub mod cache;
pub mod loadgen;
pub mod metrics;

use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::queue::BoundedQueue;
use crate::runtime::artifact::Manifest;

pub use backend::{Backend, BackendFactory, MachinePark, NativeBackend,
                  NativeEngine, Output, ShardKey, SimBackend, WorkItem};
pub use cache::LruCache;
pub use metrics::ServeMetrics;

/// Why a request did not produce an output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The serve layer is shut down; the request was rejected at
    /// admission (explicitly — never a dangling channel).
    Closed,
    /// `cancel()` was called before this request executed.
    Cancelled,
    /// The backend refused or failed the request.
    Backend(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Closed => {
                write!(f, "serve layer closed: request rejected")
            }
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::Backend(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A served request's full story.
#[derive(Debug, Clone)]
pub struct ServeReply {
    /// Label of the shard that served it (e.g. `sim:KNL`, `native`).
    pub shard: String,
    pub output: Output,
    /// Size of the coalesced group this request was served in.
    pub batch_size: usize,
    /// Wait from submission to the start of execution, seconds.
    pub queue_seconds: f64,
    /// Whether the result came from the shard's LRU cache.
    pub cache_hit: bool,
    /// Worker index within the shard.
    pub worker: usize,
}

pub type ReplyRx = Receiver<Result<ServeReply, ServeError>>;

/// Reply continuation, invoked exactly once per request — by a shard
/// worker, or by the admission path on rejection. Adapters (the
/// Scheduler/GemmService shims) use this to translate the reply type
/// without forwarder threads.
pub type ReplyFn = Box<dyn FnOnce(Result<ServeReply, ServeError>) + Send>;

struct ServeRequest {
    item: WorkItem,
    reply: ReplyFn,
    enqueued: Instant,
}

/// Where the native shard gets its artifacts.
#[derive(Debug, Clone)]
pub enum NativeConfig {
    /// Load `manifest.json` from this directory (PJRT path, with host
    /// reference-GEMM fallback when device execution is unavailable).
    Artifacts(PathBuf),
    /// Manifest-less synthetic catalog from parseable artifact ids
    /// (host reference GEMM only) — for load tests without artifacts.
    Synthetic(Vec<String>),
}

/// Serve-layer tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Front (admission) queue capacity.
    pub front_cap: usize,
    /// Per-shard queue capacity.
    pub shard_cap: usize,
    /// Maximum requests coalesced per `pop_batch`.
    pub max_batch: usize,
    /// LRU result-cache entries per shard; 0 disables caching
    /// (measurement-oriented callers must re-execute every request).
    pub cache_cap: usize,
    /// Worker threads per simulated shard (the native shard always has
    /// exactly one — its PJRT client is single-owner).
    pub sim_threads: usize,
    pub native: Option<NativeConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { front_cap: 64, shard_cap: 64, max_batch: 8, cache_cap: 0,
               sim_threads: 1, native: None }
    }
}

enum NativeSource {
    Manifest(Manifest),
    Synthetic(Vec<String>),
}

struct ShardHandle {
    queue: Arc<BoundedQueue<ServeRequest>>,
    workers: Vec<JoinHandle<()>>,
}

/// Handle to a running serve layer.
pub struct Serve {
    front: Arc<BoundedQueue<ServeRequest>>,
    dispatcher: Option<JoinHandle<()>>,
    pub metrics: Arc<ServeMetrics>,
    cancel: Arc<AtomicBool>,
    park: Arc<MachinePark>,
}

impl Serve {
    /// Start the layer. The native manifest (when configured) is loaded
    /// eagerly so configuration errors surface here, not on the first
    /// artifact request; shard threads spawn lazily on first use.
    pub fn start(cfg: ServeConfig) -> crate::Result<Serve> {
        let native_src = match &cfg.native {
            None => None,
            Some(NativeConfig::Artifacts(dir)) => {
                Some(NativeSource::Manifest(Manifest::load(dir)?))
            }
            Some(NativeConfig::Synthetic(ids)) => {
                // validate ids eagerly
                for id in ids {
                    if backend::parse_artifact_id(id).is_none() {
                        anyhow::bail!(
                            "unsupported synthetic artifact id {id:?}");
                    }
                }
                Some(NativeSource::Synthetic(ids.clone()))
            }
        };
        let front: Arc<BoundedQueue<ServeRequest>> =
            Arc::new(BoundedQueue::new(cfg.front_cap.max(1)));
        let metrics = Arc::new(ServeMetrics::new());
        let cancel = Arc::new(AtomicBool::new(false));
        let park = Arc::new(MachinePark::default());
        let dispatcher = {
            let front = Arc::clone(&front);
            let metrics = Arc::clone(&metrics);
            let cancel = Arc::clone(&cancel);
            let park = Arc::clone(&park);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name("serve-dispatch".into())
                .spawn(move || {
                    dispatch_loop(front, cfg, native_src, park, metrics,
                                  cancel)
                })
                .expect("spawn serve dispatcher")
        };
        Ok(Serve { front, dispatcher: Some(dispatcher), metrics, cancel,
                   park })
    }

    /// Submit a work item. Blocks while the front queue is full
    /// (admission control). The returned channel ALWAYS yields exactly
    /// one explicit result — after shutdown that result is
    /// `Err(ServeError::Closed)`, never a dangling disconnect.
    pub fn submit(&self, item: WorkItem) -> ReplyRx {
        let (tx, rx) = channel();
        self.submit_with(item, Box::new(move |r| {
            let _ = tx.send(r);
        }));
        rx
    }

    /// Submit with a reply continuation instead of a channel. The
    /// continuation runs exactly once — with `Err(ServeError::Closed)`
    /// synchronously when admission is already shut down.
    pub fn submit_with(&self, item: WorkItem, reply: ReplyFn) {
        self.metrics.request_submitted();
        // Depth high-water comes from the queue's own max_depth (one
        // lock inside push), not a separate len() read per request.
        let req = ServeRequest { item, reply,
                                 enqueued: Instant::now() };
        if let Err(req) = self.front.push_or_return(req) {
            self.metrics.request_failed();
            (req.reply)(Err(ServeError::Closed));
        }
    }

    /// Like [`Serve::submit`] but reports shutdown on the call itself.
    pub fn try_submit(&self, item: WorkItem)
                      -> Result<ReplyRx, ServeError> {
        if self.front.is_closed() {
            self.metrics.request_submitted();
            self.metrics.request_failed();
            return Err(ServeError::Closed);
        }
        Ok(self.submit(item))
    }

    /// Submit and wait.
    pub fn call(&self, item: WorkItem) -> Result<ServeReply, ServeError> {
        // recv error cannot happen (every request gets an explicit
        // reply); map it to Closed defensively rather than panicking.
        self.submit(item).recv().unwrap_or(Err(ServeError::Closed))
    }

    /// Request cancellation: queued work is drained and replied to with
    /// [`ServeError::Cancelled`] instead of executing.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::SeqCst)
    }

    /// Stop admission (idempotent). Queued requests still complete;
    /// subsequent `submit`s get an explicit `Closed` error.
    pub fn close(&self) {
        self.front.close();
    }

    /// Current front-queue depth (for admission metrics).
    pub fn front_depth(&self) -> usize {
        self.front.len()
    }

    /// High-water mark of the front queue since start (tracked inside
    /// the queue itself — no per-request metric calls on the hot path).
    pub fn front_depth_high_water(&self) -> usize {
        self.front.max_depth()
    }

    /// Unified metrics summary with the queue-depth high-water marks
    /// folded in (they live in the queues until read).
    pub fn summary(&self) -> String {
        self.metrics.observe_front_depth(self.front.max_depth());
        self.metrics.summary()
    }

    /// The shared machine-model registry (pre-warm, inspection).
    pub fn park(&self) -> &Arc<MachinePark> {
        &self.park
    }

    /// Graceful shutdown: close admission, drain, join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.front.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Serve {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn dispatch_loop(front: Arc<BoundedQueue<ServeRequest>>, cfg: ServeConfig,
                 mut native_src: Option<NativeSource>,
                 park: Arc<MachinePark>, metrics: Arc<ServeMetrics>,
                 cancel: Arc<AtomicBool>) {
    use std::collections::VecDeque;
    use std::time::Duration;

    let mut shards: HashMap<ShardKey, ShardHandle> = HashMap::new();
    // Per-shard overflow buffers: when one shard's queue is full, its
    // requests wait HERE instead of blocking the dispatcher — a slow
    // native shard must not head-of-line-block sim traffic sitting
    // behind it in the single front queue. Bounded: past the limit the
    // dispatcher blocks on the saturated shard only (memory stays
    // bounded; other shards were already routed).
    let mut overflow: HashMap<ShardKey, VecDeque<ServeRequest>> =
        HashMap::new();
    let mut overflow_len = 0usize;
    let overflow_limit = cfg.front_cap.max(16) * 4;
    let mut front_open = true;

    while front_open || overflow_len > 0 {
        // 1. Flush overflows opportunistically (FIFO per shard).
        for (key, buf) in overflow.iter_mut() {
            let handle = shards.get(key).expect("overflow implies shard");
            while let Some(req) = buf.pop_front() {
                match handle.queue.try_push(req) {
                    Ok(()) => overflow_len -= 1,
                    Err(req) => {
                        buf.push_front(req);
                        break;
                    }
                }
            }
        }
        if !front_open {
            // Nothing new can arrive: drain remaining overflow with
            // blocking pushes (shard queues are still open — they close
            // below, after this loop).
            for (key, buf) in overflow.iter_mut() {
                let handle =
                    shards.get(key).expect("overflow implies shard");
                for req in buf.drain(..) {
                    overflow_len -= 1;
                    if let Err(req) = handle.queue.push_or_return(req) {
                        metrics.request_failed();
                        (req.reply)(Err(ServeError::Closed));
                    }
                }
            }
            break;
        }

        // 2. Take the next burst from the front queue. With overflow
        // pending we only poll briefly so stalled shards keep getting
        // flush attempts; otherwise we block until work or close.
        let burst = if overflow_len == 0 {
            let b = front.pop_batch(32);
            if b.is_empty() {
                front_open = false;
                continue;
            }
            b
        } else {
            match front.pop_batch_timeout(32, Duration::from_millis(1)) {
                Ok(b) => b, // possibly empty: timeout → retry flush
                Err(_closed) => {
                    front_open = false;
                    continue;
                }
            }
        };

        // 3. Route the burst.
        for req in burst {
            let key = req.item.shard_key();
            if !shards.contains_key(&key) {
                match spawn_shard(key, &cfg, &mut native_src, &park,
                                  &metrics, &cancel) {
                    Ok(handle) => {
                        shards.insert(key, handle);
                    }
                    Err(e) => {
                        metrics.request_failed();
                        (req.reply)(Err(ServeError::Backend(
                            format!("{}: {e}", key.label()))));
                        continue;
                    }
                }
            }
            let handle = shards.get(&key).expect("just ensured");
            let buf = overflow.entry(key).or_default();
            if buf.is_empty() {
                match handle.queue.try_push(req) {
                    Ok(()) => continue,
                    Err(req) => {
                        buf.push_back(req);
                        overflow_len += 1;
                    }
                }
            } else {
                // keep FIFO: never jump the shard's waiting line
                buf.push_back(req);
                overflow_len += 1;
            }
            // Memory bound: block on the saturated shard only.
            while overflow_len >= overflow_limit {
                let Some(req) = buf.pop_front() else { break };
                overflow_len -= 1;
                if let Err(req) = handle.queue.push_or_return(req) {
                    metrics.request_failed();
                    (req.reply)(Err(ServeError::Closed));
                }
            }
        }
    }

    for handle in shards.values() {
        handle.queue.close();
    }
    // Fold the per-queue high-water marks into the shared metrics now
    // that routing is over (cheaper than per-request observation).
    metrics.observe_front_depth(front.max_depth());
    for (_, handle) in shards.drain() {
        metrics.observe_shard_depth(handle.queue.max_depth());
        for w in handle.workers {
            let _ = w.join();
        }
    }
}

fn spawn_shard(key: ShardKey, cfg: &ServeConfig,
               native_src: &mut Option<NativeSource>,
               park: &Arc<MachinePark>, metrics: &Arc<ServeMetrics>,
               cancel: &Arc<AtomicBool>)
               -> Result<ShardHandle, String> {
    let queue: Arc<BoundedQueue<ServeRequest>> =
        Arc::new(BoundedQueue::new(cfg.shard_cap.max(1)));
    let cache: Arc<Mutex<LruCache<Output>>> =
        Arc::new(Mutex::new(LruCache::new(cfg.cache_cap)));
    let threads = match key {
        ShardKey::Sim(_) => cfg.sim_threads.max(1),
        ShardKey::Native => 1, // single-owner: the PJRT client is Rc-based
    };
    let mut factories: Vec<BackendFactory> = Vec::new();
    match key {
        ShardKey::Sim(arch) => {
            for _ in 0..threads {
                let park = Arc::clone(park);
                factories.push(Box::new(move || {
                    Ok(Box::new(SimBackend::new(arch, &park))
                       as Box<dyn Backend>)
                }));
            }
        }
        ShardKey::Native => {
            let src = native_src.take().ok_or_else(|| {
                "no native backend configured (start the serve layer \
                 with ServeConfig::native set)".to_string()
            })?;
            factories.push(Box::new(move || {
                let b = match src {
                    NativeSource::Manifest(m) => {
                        NativeBackend::from_manifest(m)
                    }
                    NativeSource::Synthetic(ids) => {
                        NativeBackend::synthetic(&ids)?
                    }
                };
                Ok(Box::new(b) as Box<dyn Backend>)
            }));
        }
    }
    let workers = factories
        .into_iter()
        .enumerate()
        .map(|(widx, factory)| {
            let queue = Arc::clone(&queue);
            let cache = Arc::clone(&cache);
            let metrics = Arc::clone(metrics);
            let cancel = Arc::clone(cancel);
            let label = key.label();
            let max_batch = cfg.max_batch.max(1);
            std::thread::Builder::new()
                .name(format!("serve-{}-{widx}", label.replace(':', "-")))
                .spawn(move || {
                    shard_loop(queue, factory, cache, metrics, cancel,
                               max_batch, widx, label)
                })
                .expect("spawn shard worker")
        })
        .collect();
    Ok(ShardHandle { queue, workers })
}

fn shard_loop(queue: Arc<BoundedQueue<ServeRequest>>,
              factory: BackendFactory,
              cache: Arc<Mutex<LruCache<Output>>>,
              metrics: Arc<ServeMetrics>, cancel: Arc<AtomicBool>,
              max_batch: usize, worker: usize, label: String) {
    let mut backend = match factory() {
        Ok(b) => b,
        Err(e) => {
            // Init failed: every request — queued now or later — gets an
            // explicit error until the queue closes.
            loop {
                let batch = queue.pop_batch(max_batch);
                if batch.is_empty() {
                    return;
                }
                for req in batch {
                    metrics.request_failed();
                    (req.reply)(Err(ServeError::Backend(
                        format!("{label}: backend init failed: {e}"))));
                }
            }
        }
    };
    loop {
        let batch = queue.pop_batch(max_batch);
        if batch.is_empty() {
            return; // closed and drained
        }
        // Continuous batching: group the drained requests by work key
        // (first-appearance order) and serve each group with ONE
        // backend execution.
        let mut order: Vec<String> = Vec::new();
        let mut groups: HashMap<String, Vec<ServeRequest>> =
            HashMap::new();
        for req in batch {
            let key = req.item.cache_key();
            groups.entry(key.clone()).or_insert_with(|| {
                order.push(key);
                Vec::new()
            }).push(req);
        }
        for key in order {
            let group = groups.remove(&key).expect("grouped above");
            let batch_size = group.len();
            metrics.observe_batch(batch_size);

            if cancel.load(Ordering::SeqCst) {
                for req in group {
                    metrics.request_cancelled();
                    (req.reply)(Err(ServeError::Cancelled));
                }
                continue;
            }

            let (cached, cache_enabled) = {
                let mut c = cache.lock().expect("cache poisoned");
                (c.get(&key), c.enabled())
            };
            if let Some(output) = cached {
                metrics.cache_hit(batch_size as u64);
                for req in group {
                    let latency = req.enqueued.elapsed().as_secs_f64();
                    metrics.request_completed(latency);
                    (req.reply)(Ok(ServeReply {
                        shard: label.clone(),
                        output: output.clone(),
                        batch_size,
                        queue_seconds: latency,
                        cache_hit: true,
                        worker,
                    }));
                }
                continue;
            }
            if cache_enabled {
                // Serving semantics: equal work keys are interchangeable
                // — ONE execution answers the whole group and seeds the
                // cache.
                metrics.cache_miss(batch_size as u64);
                let waits: Vec<f64> = group
                    .iter()
                    .map(|r| r.enqueued.elapsed().as_secs_f64())
                    .collect();
                match backend.run(&group[0].item) {
                    Ok(output) => {
                        cache.lock().expect("cache poisoned")
                            .put(key, output.clone());
                        for (req, wait) in group.into_iter().zip(waits) {
                            let latency =
                                req.enqueued.elapsed().as_secs_f64();
                            metrics.request_completed(latency);
                            (req.reply)(Ok(ServeReply {
                                shard: label.clone(),
                                output: output.clone(),
                                batch_size,
                                queue_seconds: wait,
                                cache_hit: false,
                                worker,
                            }));
                        }
                    }
                    Err(msg) => {
                        for req in group {
                            metrics.request_failed();
                            (req.reply)(Err(ServeError::Backend(
                                msg.clone())));
                        }
                    }
                }
            } else {
                // Measurement semantics (cache disabled — the Scheduler
                // and GemmService shims): EVERY request executes, so
                // per-request timings are real observations, never a
                // duplicated clone. Batching still amortises queue
                // churn and is reported via batch_size.
                for req in group {
                    let wait = req.enqueued.elapsed().as_secs_f64();
                    match backend.run(&req.item) {
                        Ok(output) => {
                            let latency =
                                req.enqueued.elapsed().as_secs_f64();
                            metrics.request_completed(latency);
                            (req.reply)(Ok(ServeReply {
                                shard: label.clone(),
                                output,
                                batch_size,
                                queue_seconds: wait,
                                cache_hit: false,
                                worker,
                            }));
                        }
                        Err(msg) => {
                            metrics.request_failed();
                            (req.reply)(Err(ServeError::Backend(msg)));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ArchId, CompilerId};
    use crate::gemm::Precision;
    use crate::sim::TuningPoint;

    fn knl_point(t: u64) -> WorkItem {
        WorkItem::Point(TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                         Precision::F64, 1024, t, 1))
    }

    #[test]
    fn sim_call_roundtrip() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let reply = serve.call(knl_point(64)).unwrap();
        assert_eq!(reply.shard, "sim:knl");
        assert!(!reply.cache_hit);
        match reply.output {
            Output::Sim { record, .. } => assert!(record.gflops > 0.0),
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn cache_hits_on_repeat() {
        let cfg = ServeConfig { cache_cap: 16, ..Default::default() };
        let serve = Serve::start(cfg).unwrap();
        let first = serve.call(knl_point(32)).unwrap();
        assert!(!first.cache_hit);
        let second = serve.call(knl_point(32)).unwrap();
        assert!(second.cache_hit);
        assert!(serve.metrics.cache_hits() >= 1);
        assert!(serve.metrics.cache_hit_rate() > 0.0);
        serve.shutdown();
    }

    #[test]
    fn submit_after_close_gets_explicit_error() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        serve.close();
        let rx = serve.submit(knl_point(16));
        assert!(matches!(rx.recv().unwrap(), Err(ServeError::Closed)));
        assert!(matches!(serve.try_submit(knl_point(16)),
                         Err(ServeError::Closed)));
        serve.shutdown();
    }

    #[test]
    fn cancel_replies_cancelled_not_silence() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        serve.cancel();
        let rx = serve.submit(knl_point(64));
        match rx.recv().unwrap() {
            Err(ServeError::Cancelled) | Ok(_) => {} // race with dispatch
            other => panic!("unexpected {other:?}"),
        }
        assert!(serve.cancelled());
        serve.shutdown();
    }

    #[test]
    fn native_unconfigured_is_explicit_backend_error() {
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let err = serve
            .call(WorkItem::Artifact("dot_n64_f32".into()))
            .unwrap_err();
        match err {
            ServeError::Backend(m) => {
                assert!(m.contains("no native backend"), "{m}");
            }
            other => panic!("unexpected {other:?}"),
        }
        serve.shutdown();
    }

    #[test]
    fn synthetic_native_shard_serves() {
        let cfg = ServeConfig {
            cache_cap: 8,
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n64_f32".to_string(),
            ])),
            ..Default::default()
        };
        let serve = Serve::start(cfg).unwrap();
        let r = serve.call(WorkItem::Artifact("dot_n64_f32".into()))
            .unwrap();
        assert_eq!(r.shard, "native");
        match r.output {
            Output::Native { seconds, engine, .. } => {
                assert!(seconds > 0.0);
                assert_eq!(engine, NativeEngine::HostGemm);
            }
            other => panic!("unexpected {other:?}"),
        }
        let again = serve.call(WorkItem::Artifact("dot_n64_f32".into()))
            .unwrap();
        assert!(again.cache_hit);
        serve.shutdown();
    }

    #[test]
    fn bad_synthetic_ids_rejected_at_start() {
        let cfg = ServeConfig {
            native: Some(NativeConfig::Synthetic(vec![
                "mlp_b32_f32".to_string(),
            ])),
            ..Default::default()
        };
        assert!(Serve::start(cfg).is_err());
    }

    #[test]
    fn shutdown_drains_all_pending_requests() {
        let serve = Serve::start(ServeConfig {
            front_cap: 64,
            ..Default::default()
        }).unwrap();
        let rxs: Vec<_> = (0..24)
            .map(|i| serve.submit(knl_point([16, 32, 64][i % 3])))
            .collect();
        serve.shutdown(); // must drain, not drop
        let mut ok = 0;
        for rx in rxs {
            match rx.recv().expect("explicit reply even after shutdown") {
                Ok(_) => ok += 1,
                Err(e) => panic!("pre-shutdown request failed: {e}"),
            }
        }
        assert_eq!(ok, 24, "zero silent drops on shutdown");
    }
}
