//! Unified serve-layer metrics: request counters, cache hit rate,
//! queue-depth high-water marks, throughput, and end-to-end latency
//! percentiles from a lock-free log-scale histogram.
//!
//! One instance is shared by the front queue, the dispatcher and every
//! shard — the single pane of glass the ROADMAP's serving goal needs
//! (the per-subsystem counters of `coordinator::Metrics` remain only as
//! a compatibility view fed by the Scheduler shim).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Number of log-scale latency buckets: bucket `i` holds samples in
/// `[2^i, 2^(i+1))` microseconds; the last bucket is unbounded.
const BUCKETS: usize = 40;

/// Lock-free latency histogram, microsecond resolution, power-of-two
/// buckets. Quantiles are read as the upper edge of the bucket where the
/// cumulative count crosses the rank — at most a 2x overestimate, which
/// is the right bias for serving SLOs (never under-report a percentile).
#[derive(Debug)]
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // std has no Default for arrays this long; build explicitly.
        Self { counts: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn bucket_of(seconds: f64) -> usize {
        let us = (seconds * 1e6).max(0.0);
        if us < 1.0 {
            return 0;
        }
        ((us as u64).ilog2() as usize).min(BUCKETS - 1)
    }

    /// Upper edge of bucket `i` in seconds.
    fn upper_edge(i: usize) -> f64 {
        (1u64 << (i as u32 + 1).min(63)) as f64 / 1e6
    }

    pub fn record(&self, seconds: f64) {
        self.counts[Self::bucket_of(seconds)]
            .fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Quantile in seconds (`q` in [0, 1]); 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64)
            .max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= rank {
                return Self::upper_edge(i);
            }
        }
        Self::upper_edge(BUCKETS - 1)
    }
}

/// The serve layer's shared metrics. All methods are lock-free.
#[derive(Debug)]
pub struct ServeMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    /// High-water mark of the front (admission) queue.
    front_depth_hw: AtomicUsize,
    /// High-water mark across all shard queues.
    shard_depth_hw: AtomicUsize,
    /// Largest coalesced batch observed.
    max_batch: AtomicUsize,
    /// End-to-end latency: submit → reply.
    pub latency: LatencyHistogram,
    started: Instant,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            front_depth_hw: AtomicUsize::new(0),
            shard_depth_hw: AtomicUsize::new(0),
            max_batch: AtomicUsize::new(0),
            latency: LatencyHistogram::new(),
            started: Instant::now(),
        }
    }

    pub fn request_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// A request finished successfully; records its end-to-end latency.
    pub fn request_completed(&self, latency_seconds: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency_seconds);
    }

    pub fn request_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn request_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cache_hit(&self, n: u64) {
        self.cache_hits.fetch_add(n, Ordering::Relaxed);
    }

    pub fn cache_miss(&self, n: u64) {
        self.cache_misses.fetch_add(n, Ordering::Relaxed);
    }

    pub fn observe_front_depth(&self, depth: usize) {
        self.front_depth_hw.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn observe_shard_depth(&self, depth: usize) {
        self.shard_depth_hw.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn observe_batch(&self, size: usize) {
        self.max_batch.fetch_max(size, Ordering::Relaxed);
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    pub fn cache_misses(&self) -> u64 {
        self.cache_misses.load(Ordering::Relaxed)
    }

    /// Hits / (hits + misses); 0.0 before any lookup.
    pub fn cache_hit_rate(&self) -> f64 {
        let h = self.cache_hits() as f64;
        let m = self.cache_misses() as f64;
        if h + m == 0.0 { 0.0 } else { h / (h + m) }
    }

    pub fn front_depth_high_water(&self) -> usize {
        self.front_depth_hw.load(Ordering::Relaxed)
    }

    pub fn shard_depth_high_water(&self) -> usize {
        self.shard_depth_hw.load(Ordering::Relaxed)
    }

    pub fn max_batch_observed(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed)
    }

    /// Completed requests per wall-clock second since construction.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        self.completed() as f64 / secs
    }

    pub fn p50(&self) -> f64 {
        self.latency.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.latency.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.latency.quantile(0.99)
    }

    /// Human summary line for CLIs and benches.
    pub fn summary(&self) -> String {
        format!(
            "serve: {} submitted, {} ok, {} failed, {} cancelled; \
             cache {:.0}% ({}H/{}M); depth hw front={} shard={}; \
             max batch {}; p50={:.3}ms p95={:.3}ms p99={:.3}ms; \
             {:.1} req/s",
            self.submitted(), self.completed(), self.failed(),
            self.cancelled(), 100.0 * self.cache_hit_rate(),
            self.cache_hits(), self.cache_misses(),
            self.front_depth_high_water(),
            self.shard_depth_high_water(), self.max_batch_observed(),
            1e3 * self.p50(), 1e3 * self.p95(), 1e3 * self.p99(),
            self.throughput())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_monotone() {
        let h = LatencyHistogram::new();
        for us in [1.0, 10.0, 100.0, 1000.0, 10_000.0] {
            h.record(us / 1e6);
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.0) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(1.0));
        // p100 bucket must cover the 10ms sample: upper edge >= 10ms
        assert!(h.quantile(1.0) >= 0.01);
        // p50 of this set is the 100us sample's bucket: <= 256us edge
        assert!(h.quantile(0.5) <= 512e-6);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_extremes_clamped() {
        let h = LatencyHistogram::new();
        h.record(0.0); // sub-microsecond → bucket 0
        h.record(1e9); // absurdly large → last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) > 0.0);
    }

    #[test]
    fn counters_and_rates() {
        let m = ServeMetrics::new();
        m.request_submitted();
        m.request_submitted();
        m.request_completed(0.001);
        m.request_failed();
        m.cache_hit(3);
        m.cache_miss(1);
        m.observe_front_depth(5);
        m.observe_front_depth(2);
        m.observe_batch(4);
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.failed(), 1);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(m.front_depth_high_water(), 5);
        assert_eq!(m.max_batch_observed(), 4);
        assert!(m.throughput() > 0.0);
        assert!(m.summary().contains("2 submitted"));
    }

    #[test]
    fn hit_rate_defined_before_traffic() {
        let m = ServeMetrics::new();
        assert_eq!(m.cache_hit_rate(), 0.0);
    }
}
