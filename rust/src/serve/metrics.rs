//! Unified serve-layer metrics: request counters, cache hit rate,
//! queue-depth high-water marks, throughput, per-shard compute rates
//! (aggregate GFLOP/s), and end-to-end latency percentiles from a
//! lock-free log-scale histogram.
//!
//! One instance is shared by the front queue, the dispatcher and every
//! shard — the single pane of glass the ROADMAP's serving goal needs
//! (the per-subsystem counters of `coordinator::Metrics` remain only as
//! a compatibility view fed by the Scheduler shim). Everything on the
//! per-request hot path is lock-free, with short-mutex exceptions:
//! per *executed* run (cache hits skip both), the per-shard compute
//! aggregation (native runs with a known flop count) and the
//! service-time EWMA write; only when **adaptive quotas** are
//! active, one EWMA read per routed request in the dispatcher (the
//! derived-quota observability map is written only when the value
//! changes); and, for **session-tagged** requests only, one lock of
//! the per-session tally map at submit and one at reply
//! (`session_submitted` / `session_outcome` — untagged shim traffic
//! never touches it).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Number of log-scale latency buckets: bucket `i` holds samples in
/// `[2^i, 2^(i+1))` microseconds; the last bucket is unbounded.
const BUCKETS: usize = 40;

/// Lock-free latency histogram, microsecond resolution, power-of-two
/// buckets. Quantiles are read as the upper edge of the bucket where the
/// cumulative count crosses the rank — at most a 2x overestimate, which
/// is the right bias for serving SLOs (never under-report a percentile).
#[derive(Debug)]
pub struct LatencyHistogram {
    counts: [AtomicU64; BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // std has no Default for arrays this long; build explicitly.
        Self { counts: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    fn bucket_of(seconds: f64) -> usize {
        let us = (seconds * 1e6).max(0.0);
        if us < 1.0 {
            return 0;
        }
        ((us as u64).ilog2() as usize).min(BUCKETS - 1)
    }

    /// Upper edge of bucket `i` in seconds.
    fn upper_edge(i: usize) -> f64 {
        (1u64 << (i as u32 + 1).min(63)) as f64 / 1e6
    }

    pub fn record(&self, seconds: f64) {
        self.counts[Self::bucket_of(seconds)]
            .fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Raw histogram dump for bench JSON: `(upper bucket edge in
    /// seconds, count)` for every **non-empty** bucket, ascending by
    /// edge. Percentiles computed offline from this are exactly the
    /// ones [`quantile`](Self::quantile) reports — same buckets, same
    /// upper-edge bias — so a regression dashboard can recompute any
    /// quantile without a new serve run.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let n = c.load(Ordering::Relaxed);
                (n > 0).then(|| (Self::upper_edge(i), n))
            })
            .collect()
    }

    /// Quantile in seconds (`q` in [0, 1]); 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64)
            .max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= rank {
                return Self::upper_edge(i);
            }
        }
        Self::upper_edge(BUCKETS - 1)
    }
}

/// Per-shard compute aggregate: executed native runs, their summed
/// wall time and their summed floating-point work — so the aggregate
/// GFLOP/s is work-weighted (`flops / seconds`), not an average of
/// per-run rates.
#[derive(Debug, Default, Clone, Copy)]
struct ComputeAgg {
    runs: u64,
    seconds: f64,
    flops: f64,
}

/// How one session-tagged request resolved, as observed by the client
/// plane (`client::Session` reports these — `Cancelled` means the
/// caller dropped the pending handle, not that the serve layer's
/// `cancel()` fired).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionOutcome {
    Ok,
    Shed,
    Failed,
    Cancelled,
}

/// Per-session request tally — the serve layer's fairness
/// observability: one row per `client::Session`, surfaced in
/// [`ServeMetrics::summary`] so a greedy session is visible next to
/// the ones it competes with.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SessionTally {
    pub submitted: u64,
    pub ok: u64,
    pub shed: u64,
    pub failed: u64,
    pub cancelled: u64,
}

/// Per-model serve tally — the model plane's observability row: one
/// per model id, surfaced in [`ServeMetrics::summary`]. `submitted` /
/// `completed` / `failed` count whole plans (one `submit_model` each);
/// the `nodes_*` fields count the layer nodes inside them, so partial
/// failures are attributable (a failed plan with one failed node and
/// one skipped dependent is exactly that, not a mystery).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ModelTally {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub nodes_ok: u64,
    pub nodes_failed: u64,
    pub nodes_skipped: u64,
}

/// The serve layer's shared metrics. All per-request methods are
/// lock-free; see the module docs for the short-mutex exceptions.
#[derive(Debug)]
pub struct ServeMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    /// Requests shed by overload control (quota rejection or deadline
    /// expiry) — always via an explicit `Overloaded` reply, never a
    /// silent drop.
    shed: AtomicU64,
    /// Memory-LRU hits.
    cache_hits: AtomicU64,
    /// Persistent (disk) result-cache hits — counted separately so the
    /// `cache:mem` / `cache:disk` split in replies has a metrics twin.
    cache_hits_disk: AtomicU64,
    cache_misses: AtomicU64,
    /// Entries evicted from the bounded persistent (disk) result
    /// cache (oldest-first on insert).
    cache_evictions_disk: AtomicU64,
    /// High-water mark of the front (admission) queue.
    front_depth_hw: AtomicUsize,
    /// High-water mark across all shard queues.
    shard_depth_hw: AtomicUsize,
    /// Largest coalesced batch observed.
    max_batch: AtomicUsize,
    /// Background tuning jobs enqueued to the `tune:explore` shard.
    tune_enqueued: AtomicU64,
    /// Tuning jobs that completed (store hit or committed exploration).
    tune_completed: AtomicU64,
    /// Tuning jobs shed at enqueue (the tuner shard's line was full —
    /// serving traffic must never wait on tuning, so the job is
    /// dropped, counted here, and retried by a later request).
    tune_shed: AtomicU64,
    /// Tuning jobs that failed or were cancelled.
    tune_failed: AtomicU64,
    /// Shard workers whose backend panicked, was caught, and was
    /// respawned from the factory (the in-flight reply is preserved —
    /// supervision, not silent death).
    worker_restarts: AtomicU64,
    /// Execution attempts repeated under the retry policy (one per
    /// re-run, so a request retried twice counts twice).
    requests_retried: AtomicU64,
    /// Requests whose retry budget ran out — the failure the caller
    /// finally saw was preceded by `max_attempts - 1` retries.
    retries_exhausted: AtomicU64,
    /// Executions that failed the oracle digest check
    /// (`ServeError::Corrupted`): the backend ran but produced bytes
    /// disagreeing with the sequential reference.
    requests_corrupted: AtomicU64,
    /// Requests failed fast at routing because their artifact is
    /// quarantined (`ServeError::Quarantined`) — no execution spent.
    requests_quarantined: AtomicU64,
    /// Artifacts that entered quarantine (breaker opened).
    quarantine_entered: AtomicU64,
    /// Artifacts that left quarantine (half-open probe re-validated).
    quarantine_exited: AtomicU64,
    /// End-to-end latency: submit → reply.
    pub latency: LatencyHistogram,
    /// Per-shard compute aggregates (executed native runs only — cache
    /// hits do no compute and are excluded by construction).
    compute: Mutex<BTreeMap<String, ComputeAgg>>,
    /// Per-shard EWMA of observed *service* time (execution only, not
    /// queueing) in seconds — the signal adaptive quotas derive from.
    service_ewma: Mutex<BTreeMap<String, f64>>,
    /// Per-shard quota most recently derived by the dispatcher's
    /// adaptive-quota path (observability: surfaced in `summary()`).
    derived_quota: Mutex<BTreeMap<String, usize>>,
    /// Per-session request tallies (fair-admission observability),
    /// keyed by session id.
    sessions: Mutex<BTreeMap<u64, SessionTally>>,
    /// Per-model plan tallies (model-plane observability), keyed by
    /// model id.
    models: Mutex<BTreeMap<String, ModelTally>>,
    started: Instant,
    /// Nanoseconds after `started` of the first submission
    /// (`u64::MAX` = none yet) and the latest completion (0 = none
    /// yet). Throughput is measured over this window, so a warm but
    /// idle layer reports a stable rate instead of one that decays
    /// with wall-clock time since construction.
    first_submit_ns: AtomicU64,
    last_completion_ns: AtomicU64,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_hits_disk: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions_disk: AtomicU64::new(0),
            front_depth_hw: AtomicUsize::new(0),
            shard_depth_hw: AtomicUsize::new(0),
            max_batch: AtomicUsize::new(0),
            tune_enqueued: AtomicU64::new(0),
            tune_completed: AtomicU64::new(0),
            tune_shed: AtomicU64::new(0),
            tune_failed: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            requests_retried: AtomicU64::new(0),
            retries_exhausted: AtomicU64::new(0),
            requests_corrupted: AtomicU64::new(0),
            requests_quarantined: AtomicU64::new(0),
            quarantine_entered: AtomicU64::new(0),
            quarantine_exited: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
            compute: Mutex::new(BTreeMap::new()),
            service_ewma: Mutex::new(BTreeMap::new()),
            derived_quota: Mutex::new(BTreeMap::new()),
            sessions: Mutex::new(BTreeMap::new()),
            models: Mutex::new(BTreeMap::new()),
            started: Instant::now(),
            first_submit_ns: AtomicU64::new(u64::MAX),
            last_completion_ns: AtomicU64::new(0),
        }
    }

    /// Nanoseconds since construction, saturating (u64 covers ~584
    /// years of nanos — saturation is purely defensive).
    fn now_ns(&self) -> u64 {
        u64::try_from(self.started.elapsed().as_nanos())
            .unwrap_or(u64::MAX - 1)
    }

    pub fn request_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.first_submit_ns.fetch_min(self.now_ns(), Ordering::Relaxed);
    }

    /// A request finished successfully; records its end-to-end latency.
    pub fn request_completed(&self, latency_seconds: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency_seconds);
        self.last_completion_ns.fetch_max(self.now_ns(),
                                          Ordering::Relaxed);
    }

    pub fn request_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// A request was shed by overload control (explicit `Overloaded`
    /// reply — quota rejection at admission or deadline expiry at
    /// dequeue).
    pub fn request_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn request_cancelled(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn cache_hit(&self, n: u64) {
        self.cache_hits.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` requests answered from the persistent (disk) result cache.
    pub fn cache_hit_disk(&self, n: u64) {
        self.cache_hits_disk.fetch_add(n, Ordering::Relaxed);
    }

    pub fn cache_miss(&self, n: u64) {
        self.cache_misses.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` entries evicted from the bounded disk result cache.
    pub fn cache_evict_disk(&self, n: u64) {
        self.cache_evictions_disk.fetch_add(n, Ordering::Relaxed);
    }

    /// A session submitted one request (fair-admission tallies).
    pub fn session_submitted(&self, session: u64) {
        // Poisoned tallies degrade to not-counted rather than panic a
        // submit path (R2): the map is observability, not control.
        if let Ok(mut g) = self.sessions.lock() {
            g.entry(session).or_default().submitted += 1;
        }
    }

    /// A session-tagged request resolved (as observed client-side —
    /// `Cancelled` = the pending handle was dropped before the reply).
    pub fn session_outcome(&self, session: u64,
                           outcome: SessionOutcome) {
        let Ok(mut g) = self.sessions.lock() else { return };
        let t = g.entry(session).or_default();
        match outcome {
            SessionOutcome::Ok => t.ok += 1,
            SessionOutcome::Shed => t.shed += 1,
            SessionOutcome::Failed => t.failed += 1,
            SessionOutcome::Cancelled => t.cancelled += 1,
        }
    }

    /// Per-session tallies, sorted by session id (BTreeMap-backed —
    /// reports built from this are stable across runs).
    pub fn session_tallies(&self) -> Vec<(u64, SessionTally)> {
        self.sessions.lock()
            .map(|g| g.iter().map(|(id, t)| (*id, *t)).collect())
            .unwrap_or_default()
    }

    /// One model plan was submitted (`Serve::submit_model`). Same R2
    /// degrade rule as the session tallies: a poisoned map loses the
    /// count, never panics a submit path.
    pub fn model_submitted(&self, model: &str) {
        if let Ok(mut g) = self.models.lock() {
            g.entry(model.to_string()).or_default().submitted += 1;
        }
    }

    /// One model plan resolved: `ok` when every node succeeded, with
    /// the per-node breakdown (ok / failed / skipped must sum to the
    /// plan's node count — the accounting the bench gate asserts).
    pub fn model_completed(&self, model: &str, ok: bool,
                           nodes_ok: u64, nodes_failed: u64,
                           nodes_skipped: u64) {
        let Ok(mut g) = self.models.lock() else { return };
        let t = g.entry(model.to_string()).or_default();
        if ok {
            t.completed += 1;
        } else {
            t.failed += 1;
        }
        t.nodes_ok += nodes_ok;
        t.nodes_failed += nodes_failed;
        t.nodes_skipped += nodes_skipped;
    }

    /// Per-model tallies, sorted by model id (BTreeMap-backed —
    /// stable across runs).
    pub fn model_tallies(&self) -> Vec<(String, ModelTally)> {
        self.models.lock()
            .map(|g| g.iter().map(|(id, t)| (id.clone(), *t)).collect())
            .unwrap_or_default()
    }

    pub fn observe_front_depth(&self, depth: usize) {
        self.front_depth_hw.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn observe_shard_depth(&self, depth: usize) {
        self.shard_depth_hw.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn observe_batch(&self, size: usize) {
        self.max_batch.fetch_max(size, Ordering::Relaxed);
    }

    /// A shard executed one native run of `gflops` throughput over
    /// `seconds` of wall time. Called per *execution*, never per cache
    /// hit, so the aggregate reflects actual compute.
    pub fn observe_compute(&self, shard: &str, seconds: f64,
                           gflops: f64) {
        if !(seconds > 0.0) || !(gflops >= 0.0) {
            return; // defensive: never poison the aggregate with NaN
        }
        let Ok(mut g) = self.compute.lock() else { return };
        let e = g.entry(shard.to_string()).or_default();
        e.runs += 1;
        e.seconds += seconds;
        e.flops += gflops * seconds * 1e9;
    }

    /// EWMA smoothing factor for per-shard service times. 0.2 follows
    /// the new observation slowly enough to ride out batching jitter
    /// but fast enough that a mix shift re-derives quotas within a few
    /// requests.
    const SERVICE_EWMA_ALPHA: f64 = 0.2;

    /// A shard executed one request in `seconds` of service time
    /// (execution only — queue wait excluded). Feeds the per-shard
    /// EWMA adaptive quotas derive from.
    pub fn observe_service(&self, shard: &str, seconds: f64) {
        if !(seconds > 0.0) || !seconds.is_finite() {
            return; // defensive: never poison the EWMA
        }
        let Ok(mut g) = self.service_ewma.lock() else { return };
        match g.get_mut(shard) {
            Some(e) => {
                *e = Self::SERVICE_EWMA_ALPHA * seconds
                    + (1.0 - Self::SERVICE_EWMA_ALPHA) * *e;
            }
            None => {
                g.insert(shard.to_string(), seconds);
            }
        }
    }

    /// The shard's current service-time EWMA in seconds, if any
    /// request has executed there.
    pub fn service_ewma(&self, shard: &str) -> Option<f64> {
        self.service_ewma.lock().ok()?.get(shard).copied()
    }

    /// Derive an admission quota for `shard` from its service-rate
    /// EWMA and a latency budget: the number of requests the shard can
    /// serve within the budget (`budget / ewma`, at least 1) — i.e.
    /// service rate × budget. Returns `usize::MAX` (no shedding)
    /// before any observation exists: an unmeasured shard must not
    /// shed. Pure computation (one EWMA read) — the caller surfaces
    /// the value via [`ServeMetrics::record_derived_quota`] only when
    /// it changes, so the observability map is not re-written on
    /// every routed request.
    pub fn derive_quota(&self, shard: &str, budget_seconds: f64)
                        -> usize {
        let Some(ewma) = self.service_ewma(shard) else {
            return usize::MAX;
        };
        if !(ewma > 0.0) {
            return usize::MAX;
        }
        let q = (budget_seconds / ewma).floor();
        if q.is_finite() && q < usize::MAX as f64 {
            (q as usize).max(1)
        } else {
            usize::MAX
        }
    }

    /// Surface a derived adaptive quota for `summary()` /
    /// [`ServeMetrics::derived_quotas`]. `usize::MAX` (no shedding)
    /// is not worth surfacing and is ignored.
    pub fn record_derived_quota(&self, shard: &str, quota: usize) {
        if quota == usize::MAX {
            return;
        }
        if let Ok(mut g) = self.derived_quota.lock() {
            g.insert(shard.to_string(), quota);
        }
    }

    /// The live adaptive quotas most recently derived per shard,
    /// sorted by label. Empty unless the adaptive-quota path is active
    /// and has observed service times.
    pub fn derived_quotas(&self) -> Vec<(String, usize)> {
        self.derived_quota.lock()
            .map(|g| g.iter().map(|(k, v)| (k.clone(), *v)).collect())
            .unwrap_or_default()
    }

    /// A background tuning job was enqueued to the tuner shard.
    pub fn tune_job_enqueued(&self) {
        self.tune_enqueued.fetch_add(1, Ordering::Relaxed);
    }

    /// A background tuning job completed (committed or found the
    /// bucket already tuned).
    pub fn tune_job_completed(&self) {
        self.tune_completed.fetch_add(1, Ordering::Relaxed);
    }

    /// A background tuning job was shed at enqueue: the tuner shard's
    /// bounded line was full. Serving traffic is unaffected — that is
    /// the point.
    pub fn tune_job_shed(&self) {
        self.tune_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// A background tuning job failed or was cancelled.
    pub fn tune_job_failed(&self) {
        self.tune_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// A shard worker's backend panicked, was caught and respawned.
    pub fn worker_restarted(&self) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// One execution attempt was repeated under the retry policy.
    pub fn request_retried(&self) {
        self.requests_retried.fetch_add(1, Ordering::Relaxed);
    }

    /// A request's retry budget ran out; the failure goes to the
    /// caller.
    pub fn retry_exhausted(&self) {
        self.retries_exhausted.fetch_add(1, Ordering::Relaxed);
    }

    /// An execution failed the oracle digest check
    /// (`ServeError::Corrupted`).
    pub fn request_corrupted(&self) {
        self.requests_corrupted.fetch_add(1, Ordering::Relaxed);
    }

    /// A request failed fast because its artifact is quarantined
    /// (`ServeError::Quarantined`).
    pub fn request_quarantined(&self) {
        self.requests_quarantined.fetch_add(1, Ordering::Relaxed);
    }

    /// An artifact's circuit breaker opened (entered quarantine).
    pub fn quarantine_enter(&self) {
        self.quarantine_entered.fetch_add(1, Ordering::Relaxed);
    }

    /// An artifact's half-open probe re-validated it (left
    /// quarantine).
    pub fn quarantine_exit(&self) {
        self.quarantine_exited.fetch_add(1, Ordering::Relaxed);
    }

    pub fn worker_restarts(&self) -> u64 {
        self.worker_restarts.load(Ordering::Relaxed)
    }

    pub fn requests_retried(&self) -> u64 {
        self.requests_retried.load(Ordering::Relaxed)
    }

    pub fn retries_exhausted(&self) -> u64 {
        self.retries_exhausted.load(Ordering::Relaxed)
    }

    pub fn requests_corrupted(&self) -> u64 {
        self.requests_corrupted.load(Ordering::Relaxed)
    }

    pub fn requests_quarantined(&self) -> u64 {
        self.requests_quarantined.load(Ordering::Relaxed)
    }

    pub fn quarantine_entered(&self) -> u64 {
        self.quarantine_entered.load(Ordering::Relaxed)
    }

    pub fn quarantine_exited(&self) -> u64 {
        self.quarantine_exited.load(Ordering::Relaxed)
    }

    pub fn tune_enqueued(&self) -> u64 {
        self.tune_enqueued.load(Ordering::Relaxed)
    }

    pub fn tune_completed(&self) -> u64 {
        self.tune_completed.load(Ordering::Relaxed)
    }

    pub fn tune_shed(&self) -> u64 {
        self.tune_shed.load(Ordering::Relaxed)
    }

    pub fn tune_failed(&self) -> u64 {
        self.tune_failed.load(Ordering::Relaxed)
    }

    /// Per-shard aggregate compute rates: `(shard label, executed
    /// runs, work-weighted GFLOP/s)`, **sorted by shard label**
    /// (BTreeMap-backed) — load reports and bench JSON built from this
    /// are stable across runs and diffable in CI. Empty until a
    /// native run with a known flop count completes.
    pub fn compute_rates(&self) -> Vec<(String, u64, f64)> {
        let Ok(g) = self.compute.lock() else { return Vec::new() };
        g.iter()
            .map(|(label, agg)| {
                let rate = if agg.seconds > 0.0 {
                    agg.flops / agg.seconds / 1e9
                } else {
                    0.0
                };
                (label.clone(), agg.runs, rate)
            })
            .collect()
    }

    pub fn submitted(&self) -> u64 {
        self.submitted.load(Ordering::Relaxed)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Shed requests / submitted requests; 0.0 before any submission.
    pub fn shed_rate(&self) -> f64 {
        let s = self.submitted() as f64;
        if s == 0.0 { 0.0 } else { self.shed() as f64 / s }
    }

    /// Memory-LRU hits (the disk tier is counted separately in
    /// [`ServeMetrics::cache_hits_disk`]).
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    pub fn cache_hits_disk(&self) -> u64 {
        self.cache_hits_disk.load(Ordering::Relaxed)
    }

    pub fn cache_misses(&self) -> u64 {
        self.cache_misses.load(Ordering::Relaxed)
    }

    pub fn cache_evictions_disk(&self) -> u64 {
        self.cache_evictions_disk.load(Ordering::Relaxed)
    }

    /// Hits (both tiers) / (hits + misses); 0.0 before any lookup.
    pub fn cache_hit_rate(&self) -> f64 {
        let h = (self.cache_hits() + self.cache_hits_disk()) as f64;
        let m = self.cache_misses() as f64;
        if h + m == 0.0 { 0.0 } else { h / (h + m) }
    }

    pub fn front_depth_high_water(&self) -> usize {
        self.front_depth_hw.load(Ordering::Relaxed)
    }

    pub fn shard_depth_high_water(&self) -> usize {
        self.shard_depth_hw.load(Ordering::Relaxed)
    }

    pub fn max_batch_observed(&self) -> usize {
        self.max_batch.load(Ordering::Relaxed)
    }

    /// Completed requests per second over the **active window** —
    /// first submission to latest completion — not since construction,
    /// so a warm-but-idle layer reports a stable rate instead of one
    /// decaying with idle wall-clock time. 0.0 before the first
    /// completion; with exactly one completion the window is that
    /// request's service time.
    pub fn throughput(&self) -> f64 {
        let done = self.completed();
        if done == 0 {
            return 0.0;
        }
        let first = match self.first_submit_ns.load(Ordering::Relaxed) {
            u64::MAX => 0, // defensive: completion without a submit
            ns => ns,
        };
        let last = self.last_completion_ns.load(Ordering::Relaxed);
        let span_ns = last.saturating_sub(first).max(1);
        done as f64 / (span_ns as f64 / 1e9)
    }

    pub fn p50(&self) -> f64 {
        self.latency.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.latency.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.latency.quantile(0.99)
    }

    /// Human summary line for CLIs and benches. Shards that executed
    /// native compute get an aggregate GFLOP/s tail so tuning wins are
    /// visible under load.
    pub fn summary(&self) -> String {
        // two-tier cache tail: the disk split only appears once the
        // persistent cache has served anything
        let cache = if self.cache_hits_disk() > 0 {
            format!("({}Hm/{}Hd/{}M)", self.cache_hits(),
                    self.cache_hits_disk(), self.cache_misses())
        } else {
            format!("({}H/{}M)", self.cache_hits(), self.cache_misses())
        };
        let mut s = format!(
            "serve: {} submitted, {} ok, {} failed, {} shed, \
             {} cancelled; \
             cache {:.0}% {cache}; depth hw front={} shard={}; \
             max batch {}; p50={:.3}ms p95={:.3}ms p99={:.3}ms; \
             {:.1} req/s",
            self.submitted(), self.completed(), self.failed(),
            self.shed(),
            self.cancelled(), 100.0 * self.cache_hit_rate(),
            self.front_depth_high_water(),
            self.shard_depth_high_water(), self.max_batch_observed(),
            1e3 * self.p50(), 1e3 * self.p95(), 1e3 * self.p99(),
            self.throughput());
        let rates = self.compute_rates();
        if !rates.is_empty() {
            s.push_str("; compute");
            for (label, runs, gflops) in rates {
                s.push_str(&format!(
                    " {label}={gflops:.1}GF/s({runs} runs)"));
            }
        }
        let quotas = self.derived_quotas();
        if !quotas.is_empty() {
            s.push_str("; adaptive quota");
            for (label, q) in quotas {
                s.push_str(&format!(" {label}={q}"));
            }
        }
        let (enq, done, tshed, tfail) =
            (self.tune_enqueued(), self.tune_completed(),
             self.tune_shed(), self.tune_failed());
        if enq + done + tshed + tfail > 0 {
            s.push_str(&format!(
                "; tuning {enq} jobs ({done} done, {tshed} shed, \
                 {tfail} failed)"));
        }
        let (restarts, retried, exhausted) =
            (self.worker_restarts(), self.requests_retried(),
             self.retries_exhausted());
        if restarts + retried + exhausted > 0 {
            s.push_str(&format!(
                "; recovery {restarts} restarts, {retried} retried, \
                 {exhausted} exhausted"));
        }
        let (corrupt, quar, qin, qout) =
            (self.requests_corrupted(), self.requests_quarantined(),
             self.quarantine_entered(), self.quarantine_exited());
        if corrupt + quar + qin + qout > 0 {
            s.push_str(&format!(
                "; quarantine {corrupt} corrupted, {quar} failed-fast \
                 ({qin} entered, {qout} exited)"));
        }
        let evicted = self.cache_evictions_disk();
        if evicted > 0 {
            s.push_str(&format!("; disk cache evicted {evicted}"));
        }
        let sessions = self.session_tallies();
        if !sessions.is_empty() {
            s.push_str("; sessions");
            for (id, t) in sessions {
                s.push_str(&format!(
                    " s{id}={}/{}ok/{}sh/{}fl/{}cx", t.submitted,
                    t.ok, t.shed, t.failed, t.cancelled));
            }
        }
        let models = self.model_tallies();
        if !models.is_empty() {
            s.push_str("; models");
            for (id, t) in models {
                s.push_str(&format!(
                    " {id}={}/{}ok/{}fl nodes={}ok/{}fl/{}sk",
                    t.submitted, t.completed, t.failed, t.nodes_ok,
                    t.nodes_failed, t.nodes_skipped));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_monotone() {
        let h = LatencyHistogram::new();
        for us in [1.0, 10.0, 100.0, 1000.0, 10_000.0] {
            h.record(us / 1e6);
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile(0.0) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(1.0));
        // p100 bucket must cover the 10ms sample: upper edge >= 10ms
        assert!(h.quantile(1.0) >= 0.01);
        // p50 of this set is the 100us sample's bucket: <= 256us edge
        assert!(h.quantile(0.5) <= 512e-6);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert!(h.buckets().is_empty());
    }

    #[test]
    fn histogram_bucket_dump_matches_quantiles() {
        let h = LatencyHistogram::new();
        for us in [3.0, 3.0, 100.0, 5000.0] {
            h.record(us / 1e6);
        }
        let b = h.buckets();
        // three distinct buckets, ascending edges, counts sum to 4
        assert_eq!(b.len(), 3);
        assert!(b.windows(2).all(|w| w[0].0 < w[1].0));
        assert_eq!(b.iter().map(|&(_, n)| n).sum::<u64>(), 4);
        assert_eq!(b[0].1, 2, "both 3us samples share a bucket");
        // the dump's last edge is exactly the p100 the histogram
        // itself reports — offline recomputation stays faithful
        assert_eq!(b.last().unwrap().0, h.quantile(1.0));
    }

    #[test]
    fn histogram_extremes_clamped() {
        let h = LatencyHistogram::new();
        h.record(0.0); // sub-microsecond → bucket 0
        h.record(1e9); // absurdly large → last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(1.0) > 0.0);
    }

    #[test]
    fn counters_and_rates() {
        let m = ServeMetrics::new();
        m.request_submitted();
        m.request_submitted();
        m.request_completed(0.001);
        m.request_failed();
        m.cache_hit(3);
        m.cache_miss(1);
        m.observe_front_depth(5);
        m.observe_front_depth(2);
        m.observe_batch(4);
        assert_eq!(m.submitted(), 2);
        assert_eq!(m.completed(), 1);
        assert_eq!(m.failed(), 1);
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(m.front_depth_high_water(), 5);
        assert_eq!(m.max_batch_observed(), 4);
        assert!(m.throughput() > 0.0);
        assert!(m.summary().contains("2 submitted"));
    }

    #[test]
    fn hit_rate_defined_before_traffic() {
        let m = ServeMetrics::new();
        assert_eq!(m.cache_hit_rate(), 0.0);
    }

    #[test]
    fn compute_rates_are_work_weighted_per_shard() {
        let m = ServeMetrics::new();
        assert!(m.compute_rates().is_empty());
        assert!(!m.summary().contains("compute"),
                "no compute tail before any native run");
        // shard A: 10 GFLOP in 1s + 30 GFLOP in 1s → 20 GF/s aggregate
        m.observe_compute("native:threadpool", 1.0, 10.0);
        m.observe_compute("native:threadpool", 1.0, 30.0);
        m.observe_compute("native:pjrt", 0.5, 8.0);
        // junk observations must be ignored, not poison the aggregate
        m.observe_compute("native:pjrt", 0.0, 1.0);
        m.observe_compute("native:pjrt", 1.0, f64::NAN);
        let rates = m.compute_rates();
        assert_eq!(rates.len(), 2);
        assert_eq!(rates[0].0, "native:pjrt");
        assert_eq!(rates[0].1, 1);
        assert!((rates[0].2 - 8.0).abs() < 1e-9);
        assert_eq!(rates[1].0, "native:threadpool");
        assert_eq!(rates[1].1, 2);
        assert!((rates[1].2 - 20.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("compute") && s.contains("native:threadpool="),
                "{s}");
    }

    #[test]
    fn service_ewma_and_adaptive_quota_math() {
        let m = ServeMetrics::new();
        assert_eq!(m.service_ewma("sim:knl"), None);
        assert_eq!(m.derive_quota("sim:knl", 0.25), usize::MAX,
                   "no observation -> never shed");
        assert!(m.derived_quotas().is_empty(),
                "MAX derivations are not recorded");
        // first observation seeds the EWMA exactly
        m.observe_service("sim:knl", 0.010);
        assert!((m.service_ewma("sim:knl").unwrap() - 0.010).abs()
                < 1e-12);
        // EWMA follows slowly: 0.2*0.020 + 0.8*0.010 = 0.012
        m.observe_service("sim:knl", 0.020);
        assert!((m.service_ewma("sim:knl").unwrap() - 0.012).abs()
                < 1e-12);
        // quota = floor(budget / ewma) = floor(0.25 / 0.012) = 20
        assert_eq!(m.derive_quota("sim:knl", 0.25), 20);
        // a budget below one service time still admits one request
        assert_eq!(m.derive_quota("sim:knl", 1e-9), 1);
        // derivation is pure — surfacing is a separate, explicit step
        assert!(m.derived_quotas().is_empty());
        // junk observations are ignored
        m.observe_service("sim:knl", f64::NAN);
        m.observe_service("sim:knl", 0.0);
        assert!((m.service_ewma("sim:knl").unwrap() - 0.012).abs()
                < 1e-12);
        // recorded quotas are surfaced, sorted, in the summary;
        // usize::MAX (no shedding) is never surfaced
        m.record_derived_quota("sim:knl", 20);
        m.record_derived_quota("native:pjrt", 250);
        m.record_derived_quota("native:threadpool", usize::MAX);
        let quotas = m.derived_quotas();
        assert_eq!(quotas.len(), 2);
        assert_eq!(quotas[0].0, "native:pjrt");
        assert_eq!(quotas[1].0, "sim:knl");
        assert!(m.summary().contains("adaptive quota"), "{}",
                m.summary());
    }

    #[test]
    fn tune_counters_and_summary_tail() {
        let m = ServeMetrics::new();
        assert!(!m.summary().contains("tuning"),
                "no tuning tail before any job");
        m.tune_job_enqueued();
        m.tune_job_enqueued();
        m.tune_job_completed();
        m.tune_job_shed();
        m.tune_job_failed();
        assert_eq!(m.tune_enqueued(), 2);
        assert_eq!(m.tune_completed(), 1);
        assert_eq!(m.tune_shed(), 1);
        assert_eq!(m.tune_failed(), 1);
        let s = m.summary();
        assert!(s.contains("tuning 2 jobs"), "{s}");
        assert!(s.contains("1 shed,"), "{s}");
    }

    #[test]
    fn recovery_and_quarantine_counters_in_summary() {
        let m = ServeMetrics::new();
        let s = m.summary();
        assert!(!s.contains("recovery") && !s.contains("quarantine"),
                "no recovery tails before any fault: {s}");
        m.worker_restarted();
        m.request_retried();
        m.request_retried();
        m.retry_exhausted();
        m.request_corrupted();
        m.request_quarantined();
        m.quarantine_enter();
        m.quarantine_exit();
        assert_eq!(m.worker_restarts(), 1);
        assert_eq!(m.requests_retried(), 2);
        assert_eq!(m.retries_exhausted(), 1);
        assert_eq!(m.requests_corrupted(), 1);
        assert_eq!(m.requests_quarantined(), 1);
        assert_eq!(m.quarantine_entered(), 1);
        assert_eq!(m.quarantine_exited(), 1);
        let s = m.summary();
        assert!(s.contains("recovery 1 restarts, 2 retried, \
                            1 exhausted"), "{s}");
        assert!(s.contains("quarantine 1 corrupted, 1 failed-fast \
                            (1 entered, 1 exited)"), "{s}");
    }

    #[test]
    fn shed_counter_and_rate() {
        let m = ServeMetrics::new();
        assert_eq!(m.shed_rate(), 0.0, "defined before traffic");
        for _ in 0..4 {
            m.request_submitted();
        }
        m.request_shed();
        m.request_completed(0.001);
        assert_eq!(m.shed(), 1);
        assert!((m.shed_rate() - 0.25).abs() < 1e-12);
        assert!(m.summary().contains("1 shed"), "{}", m.summary());
    }

    #[test]
    fn session_tallies_sorted_and_in_summary() {
        let m = ServeMetrics::new();
        assert!(m.session_tallies().is_empty());
        assert!(!m.summary().contains("sessions"),
                "no session tail before any tagged request");
        for _ in 0..3 {
            m.session_submitted(2);
        }
        m.session_submitted(1);
        m.session_outcome(2, SessionOutcome::Ok);
        m.session_outcome(2, SessionOutcome::Shed);
        m.session_outcome(2, SessionOutcome::Cancelled);
        m.session_outcome(1, SessionOutcome::Failed);
        let t = m.session_tallies();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, 1, "sorted by session id");
        assert_eq!(t[0].1.failed, 1);
        assert_eq!(t[1].1,
                   SessionTally { submitted: 3, ok: 1, shed: 1,
                                  failed: 0, cancelled: 1 });
        let s = m.summary();
        assert!(s.contains("sessions"), "{s}");
        assert!(s.contains("s2=3/1ok/1sh/0fl/1cx"), "{s}");
    }

    #[test]
    fn model_tallies_sorted_and_in_summary() {
        let m = ServeMetrics::new();
        assert!(m.model_tallies().is_empty());
        assert!(!m.summary().contains("models"),
                "no model tail before any plan: {}", m.summary());
        m.model_submitted("mlp_b64_f32");
        m.model_submitted("mlp_b64_f32");
        m.model_submitted("ae_b32_f32");
        // one clean plan (2 nodes), one with a failure cascade
        m.model_completed("mlp_b64_f32", true, 2, 0, 0);
        m.model_completed("mlp_b64_f32", false, 0, 1, 1);
        m.model_completed("ae_b32_f32", true, 3, 0, 0);
        let t = m.model_tallies();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, "ae_b32_f32", "sorted by model id");
        assert_eq!(t[1].1,
                   ModelTally { submitted: 2, completed: 1, failed: 1,
                                nodes_ok: 2, nodes_failed: 1,
                                nodes_skipped: 1 });
        let s = m.summary();
        assert!(s.contains("models"), "{s}");
        assert!(s.contains("mlp_b64_f32=2/1ok/1fl nodes=2ok/1fl/1sk"),
                "{s}");
    }

    #[test]
    fn disk_cache_hits_counted_in_rate_and_summary() {
        let m = ServeMetrics::new();
        m.cache_hit(1);
        m.cache_miss(1);
        assert!(!m.summary().contains("Hd"),
                "no disk split before a disk hit: {}", m.summary());
        m.cache_hit_disk(2);
        assert_eq!(m.cache_hits_disk(), 2);
        // (1 mem + 2 disk) / 4 lookups
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("1Hm/2Hd/1M"), "{s}");
    }

    #[test]
    fn throughput_ignores_idle_warmup_and_does_not_decay() {
        let m = ServeMetrics::new();
        assert_eq!(m.throughput(), 0.0, "no completions yet");
        // Idle warmup before the first request must not deflate the
        // rate: the window opens at the first submit, not at new().
        std::thread::sleep(std::time::Duration::from_millis(60));
        for _ in 0..50 {
            m.request_submitted();
            m.request_completed(1e-6);
        }
        // 50 requests within far less than the 60ms warmup: the old
        // since-construction rate would be < ~833 req/s; the windowed
        // rate is orders of magnitude higher.
        assert!(m.throughput() > 2_000.0, "{} req/s", m.throughput());
        // A warm-but-idle layer must report a FROZEN rate, not a
        // decaying one: the window closes at the last completion.
        let before = m.throughput();
        std::thread::sleep(std::time::Duration::from_millis(40));
        assert_eq!(m.throughput(), before, "idle decay detected");
    }
}
