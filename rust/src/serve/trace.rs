//! Per-request tracing plane: span trees + a bounded flight recorder.
//!
//! Every request admitted while tracing is on (`ServeConfig::trace_cap
//! > 0`) carries an [`ActiveTrace`]: a lock-light span sink shared by
//! the dispatcher, the shard worker, the retry loop and the backend
//! via `Arc`. Stages open named spans ([`SpanKind`]) through RAII
//! [`SpanGuard`]s — a guard records its span on *every* exit path
//! (drop, early return, panic unwind), which is the invariant the
//! `pallas-lint` R9 span-discipline rule checks statically.
//!
//! The trace commits exactly once, when the reply fires: `submit_raw`
//! wraps the reply closure, so every terminal site (admission reject,
//! quarantine deny, shed, shutdown drain, normal completion) funnels
//! through one [`ActiveTrace::finish`]. A synthetic `queue` span is
//! added at commit covering submission → first recorded span, so even
//! a request shed before reaching a shard renders a complete tree.
//!
//! The [`TraceRecorder`] is bounded by construction: a fixed-capacity
//! ring of the most recent traces (overflow evicts oldest and counts
//! `dropped`), an exemplar list of the N slowest, and a ring of
//! failed/quarantined traces. Per-(shard, phase) duration aggregates
//! are folded on commit and feed `Serve::summary()`'s phase
//! breakdown. With `trace_cap == 0` (the default) no recorder exists
//! and every hook is a `None` check — the zero-cost off path.
//!
//! Export is Chrome trace-event JSON ([`chrome_trace`]) loadable in
//! `chrome://tracing` / Perfetto (one lane per trace id, so a
//! pipeline whose nodes share an id renders as one tree), plus a
//! text waterfall ([`waterfall`]) for terminals and CI logs.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use super::fault::FaultSite;
use super::{ServeError, ServeReply};

/// Attribute list carried by spans and traces. Keys are static — the
/// instrumentation vocabulary is closed — values are formatted once
/// at record time.
pub type Attrs = Vec<(&'static str, String)>;

/// The span taxonomy. One lifecycle stage per variant; `Retry(k)`
/// carries the 1-based retry index so the waterfall reads `retry#1`,
/// `retry#2`, … while aggregation folds them into one `retry` phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Submission → first recorded stage (synthesized at commit).
    Queue,
    /// Dispatcher routing: shard choice + quarantine admission.
    Route,
    /// Group membership: coalesced wait while the leader executes.
    Batch,
    /// Operand staging: panel packing + oracle preparation.
    Pack,
    /// Backend execution (one per attempt).
    Execute,
    /// Oracle digest verification of the produced output.
    Verify,
    /// The k-th retry decision (spans the inter-attempt gap).
    Retry(u32),
    /// Backoff sleep inside a retry gap.
    Backoff,
    /// Memory-LRU result-cache probe.
    CacheMem,
    /// Disk result-cache probe.
    CacheDisk,
    /// Online-tuner exploration inside the `tune:` shard.
    TuneExplore,
    /// Model-plane root: one per `Serve::submit_model`, covering
    /// every layer node of the plan under one trace id.
    Model,
}

impl SpanKind {
    /// Stable aggregation key: every `retry#k` folds into `retry`.
    pub fn phase(self) -> &'static str {
        match self {
            SpanKind::Queue => "queue",
            SpanKind::Route => "route",
            SpanKind::Batch => "batch",
            SpanKind::Pack => "pack",
            SpanKind::Execute => "execute",
            SpanKind::Verify => "verify",
            SpanKind::Retry(_) => "retry",
            SpanKind::Backoff => "backoff",
            SpanKind::CacheMem => "cache:mem",
            SpanKind::CacheDisk => "cache:disk",
            SpanKind::TuneExplore => "tune:explore",
            SpanKind::Model => "model",
        }
    }

    /// Display label (`retry#k` keeps its index).
    pub fn label(self) -> String {
        match self {
            SpanKind::Retry(k) => format!("retry#{k}"),
            other => other.phase().to_string(),
        }
    }

    /// Inverse of [`SpanKind::label`] — used by the `trace`
    /// subcommand to reload exported Chrome JSON.
    pub fn parse(label: &str) -> Option<SpanKind> {
        match label {
            "queue" => Some(SpanKind::Queue),
            "route" => Some(SpanKind::Route),
            "batch" => Some(SpanKind::Batch),
            "pack" => Some(SpanKind::Pack),
            "execute" => Some(SpanKind::Execute),
            "verify" => Some(SpanKind::Verify),
            "backoff" => Some(SpanKind::Backoff),
            "cache:mem" => Some(SpanKind::CacheMem),
            "cache:disk" => Some(SpanKind::CacheDisk),
            "tune:explore" => Some(SpanKind::TuneExplore),
            "model" => Some(SpanKind::Model),
            other => other
                .strip_prefix("retry#")
                .and_then(|k| k.parse().ok())
                .map(SpanKind::Retry),
        }
    }
}

/// The stable name of a [`ServeError`] variant, used for span/trace
/// `error=` attributes and the committed trace outcome.
pub fn error_variant(err: &ServeError) -> &'static str {
    match err {
        ServeError::Closed => "closed",
        ServeError::Cancelled => "cancelled",
        ServeError::Overloaded { .. } => "overloaded",
        ServeError::Backend(_) => "backend",
        ServeError::Corrupted { .. } => "corrupted",
        ServeError::Quarantined { .. } => "quarantined",
    }
}

/// Attach an error variant to the active trace if one is present —
/// the attachment hook for reply sites that hold no live span guard
/// (the R9 span-discipline rule requires every `ServeError`
/// constructed in a traced region to be attached one way or the
/// other).
pub fn attach_err(trace: &Option<Arc<ActiveTrace>>, err: &ServeError) {
    if let Some(t) = trace {
        t.attach("error", error_variant(err));
    }
}

/// One closed span: a named stage with monotonic microsecond bounds
/// (relative to the recorder epoch) and structured attributes.
#[derive(Debug, Clone)]
pub struct Span {
    pub kind: SpanKind,
    pub start_us: u64,
    pub end_us: u64,
    pub attrs: Attrs,
}

impl Span {
    pub fn micros(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    /// First value recorded for `key`, if any.
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// A committed trace: the span tree plus request-level metadata, as
/// stored in the recorder and exported to Chrome JSON.
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// Trace id — shared across a pipeline's nodes so the DAG
    /// renders as one lane.
    pub id: u64,
    /// Commit sequence number, unique per committed trace (a record
    /// can sit in the ring *and* an exemplar list; exports dedup on
    /// this).
    pub seq: u64,
    /// Work identity (the item's cache key).
    pub kernel: String,
    /// Session id the request was tagged with, if any.
    pub session: Option<u64>,
    /// `"ok"` or the [`error_variant`] of the terminal error.
    pub outcome: &'static str,
    /// Shard that answered (empty when the request never reached
    /// one, e.g. rejected at admission).
    pub shard: String,
    pub start_us: u64,
    pub end_us: u64,
    pub spans: Vec<Span>,
    /// Trace-level attributes (cache tier, attempts, batch size,
    /// attached errors/faults).
    pub attrs: Attrs,
}

impl TraceRecord {
    pub fn total_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    pub fn failed(&self) -> bool {
        self.outcome != "ok"
    }
}

#[derive(Default)]
struct TraceState {
    spans: Vec<Span>,
    attrs: Attrs,
    committed: bool,
}

/// The per-request span sink. Shared by `Arc` between the request
/// (`ServeRequest::trace`) and the wrapped reply closure; the
/// interior mutex is effectively uncontended — exactly one thread
/// works on a request at any moment — which is what keeps the
/// recording path lock-light.
pub struct ActiveTrace {
    id: u64,
    start_us: u64,
    kernel: String,
    session: Option<u64>,
    recorder: Arc<TraceRecorder>,
    state: Mutex<TraceState>,
}

impl ActiveTrace {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Microseconds since the recorder epoch — the clock every span
    /// in this trace uses.
    pub fn now_us(&self) -> u64 {
        self.recorder.now_us()
    }

    /// Open a span. The returned guard records on every exit path;
    /// bind it (`let g = …`) for the scope the stage covers — the
    /// R9 lint rule rejects guards that are dropped on the spot.
    pub fn span(self: &Arc<Self>, kind: SpanKind) -> SpanGuard {
        SpanGuard {
            trace: Arc::clone(self),
            kind,
            start_us: self.recorder.now_us(),
            attrs: Vec::new(),
        }
    }

    /// Record a span retroactively from an earlier `now_us()`
    /// timestamp to now — for stages whose start is observed in one
    /// place and whose end in another (e.g. coalesced batch waits).
    pub fn record(&self, kind: SpanKind, start_us: u64, attrs: Attrs) {
        let span = Span {
            kind,
            start_us,
            end_us: self.recorder.now_us(),
            attrs,
        };
        self.push_span(span);
    }

    /// Attach a trace-level attribute (kept once per occurrence, in
    /// record order).
    pub fn attach(&self, key: &'static str, value: impl Into<String>) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.attrs.push((key, value.into()));
    }

    fn push_span(&self, span: Span) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        if !st.committed {
            st.spans.push(span);
        }
    }

    /// Commit the trace to the recorder. Called from the wrapped
    /// reply closure, so it runs exactly when the request's single
    /// reply fires; a second call is a no-op by construction, which
    /// is what the no-double-close accounting test pins.
    pub fn finish(&self, result: &Result<ServeReply, ServeError>) {
        let end_us = self.recorder.now_us();
        let (mut spans, mut attrs) = {
            let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
            if st.committed {
                return;
            }
            st.committed = true;
            (std::mem::take(&mut st.spans), std::mem::take(&mut st.attrs))
        };
        // chronological, parents (longer spans) before their children
        spans.sort_by(|a, b| {
            a.start_us
                .cmp(&b.start_us)
                .then(b.end_us.cmp(&a.end_us))
        });
        // synthesize the queue span: submission -> first real stage
        // (or the reply itself if the request never reached one)
        let first = spans.first().map(|s| s.start_us).unwrap_or(end_us);
        spans.insert(
            0,
            Span {
                kind: SpanKind::Queue,
                start_us: self.start_us,
                end_us: first.max(self.start_us),
                attrs: Vec::new(),
            },
        );
        let (outcome, shard) = match result {
            Ok(reply) => ("ok", reply.shard.clone()),
            Err(err) => {
                let shard = match err {
                    ServeError::Overloaded { shard, .. } => shard.clone(),
                    ServeError::Corrupted { shard, .. } => shard.clone(),
                    _ => String::new(),
                };
                (error_variant(err), shard)
            }
        };
        if let Ok(reply) = result {
            attrs.push(("cache", reply.cache_src.label().to_string()));
            attrs.push(("attempts", reply.attempts.to_string()));
            attrs.push(("batch", reply.batch_size.to_string()));
        }
        self.recorder.commit(TraceRecord {
            id: self.id,
            seq: 0, // assigned by the recorder
            kernel: self.kernel.clone(),
            session: self.session,
            outcome,
            shard,
            start_us: self.start_us,
            end_us,
            spans,
            attrs,
        });
    }
}

/// RAII span handle: created by [`ActiveTrace::span`], records its
/// span when dropped — on normal scope exit, early return, or panic
/// unwind alike. Owns its `Arc`, so it can outlive moves of the
/// request that spawned it (it records no locks, so holding one
/// across a sleep or a blocking call is safe).
pub struct SpanGuard {
    trace: Arc<ActiveTrace>,
    kind: SpanKind,
    start_us: u64,
    attrs: Attrs,
}

impl SpanGuard {
    /// Add a structured attribute to this span.
    pub fn attr(&mut self, key: &'static str, value: impl Into<String>) {
        self.attrs.push((key, value.into()));
    }

    /// Mark that a fault-plane site fired inside this span
    /// (`fault=<site label>`), making chaos traces self-explaining.
    pub fn fault(&mut self, site: FaultSite) {
        self.attrs.push(("fault", site.label().to_string()));
    }

    /// Attach the error produced inside this span (`error=<variant>`).
    pub fn fail(&mut self, err: &ServeError) {
        self.attrs.push(("error", error_variant(err).to_string()));
    }

    /// Close the span now (dropping the guard does the same; this
    /// exists to make scope ends explicit at hand-off points).
    pub fn end(self) {}
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let span = Span {
            kind: self.kind,
            start_us: self.start_us,
            end_us: self.trace.recorder.now_us(),
            attrs: std::mem::take(&mut self.attrs),
        };
        self.trace.push_span(span);
    }
}

struct RecorderState {
    ring: VecDeque<TraceRecord>,
    slow: Vec<TraceRecord>,
    failed: VecDeque<TraceRecord>,
    phases: BTreeMap<(String, &'static str), u64>,
}

/// The bounded flight recorder. All storage is fixed-capacity:
///
/// * `ring` — the most recent `cap` committed traces; overflow
///   evicts oldest-first and is counted in [`TraceRecorder::dropped`].
/// * `slow` — the `exemplar_cap` slowest traces seen (pruning the
///   list is by design, not a drop).
/// * `failed` — the most recent `cap` failed/quarantined traces, so
///   errors survive ring churn under load.
///
/// Commit folds per-(shard, phase) duration sums for the summary
/// breakdown. The recorder clock is a single epoch `Instant`, so
/// every span in every trace shares one monotonic microsecond axis.
pub struct TraceRecorder {
    epoch: Instant,
    cap: usize,
    exemplar_cap: usize,
    next_id: AtomicU64,
    committed: AtomicU64,
    dropped: AtomicU64,
    inner: Mutex<RecorderState>,
}

impl TraceRecorder {
    /// `cap` bounds the ring and the failed list (clamped to >= 1);
    /// `exemplar_cap` bounds the slowest-trace list.
    pub fn new(cap: usize, exemplar_cap: usize) -> Self {
        TraceRecorder {
            epoch: Instant::now(),
            cap: cap.max(1),
            exemplar_cap,
            next_id: AtomicU64::new(0),
            committed: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            inner: Mutex::new(RecorderState {
                ring: VecDeque::new(),
                slow: Vec::new(),
                failed: VecDeque::new(),
                phases: BTreeMap::new(),
            }),
        }
    }

    /// Microseconds since the recorder epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Ring capacity (after the >= 1 clamp).
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Mint a fresh trace id. Pipelines mint one id up front and tag
    /// every node's `WorkItem` with it so the DAG shares a lane.
    pub fn mint_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Open a trace for an admitted request.
    pub fn begin(
        self: &Arc<Self>,
        id: u64,
        kernel: String,
        session: Option<u64>,
    ) -> Arc<ActiveTrace> {
        Arc::new(ActiveTrace {
            id,
            start_us: self.now_us(),
            kernel,
            session,
            recorder: Arc::clone(self),
            state: Mutex::new(TraceState::default()),
        })
    }

    /// Traces committed so far (exactly one per replied request).
    pub fn committed(&self) -> u64 {
        self.committed.load(Ordering::Relaxed)
    }

    /// Traces evicted from the bounded rings (ring overflow). The
    /// recorder never blocks or grows to avoid this — dropping
    /// oldest is the overhead contract.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    fn commit(&self, mut record: TraceRecord) {
        record.seq = self.committed.fetch_add(1, Ordering::Relaxed) + 1;
        let mut evicted = 0u64;
        {
            let mut st = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
            for span in &record.spans {
                let key = (record.shard.clone(), span.kind.phase());
                *st.phases.entry(key).or_insert(0) += span.micros();
            }
            if self.exemplar_cap > 0 {
                let at = st
                    .slow
                    .partition_point(|r| r.total_us() >= record.total_us());
                if at < self.exemplar_cap {
                    st.slow.insert(at, record.clone());
                    st.slow.truncate(self.exemplar_cap);
                }
            }
            if record.failed() {
                st.failed.push_back(record.clone());
                if st.failed.len() > self.cap {
                    st.failed.pop_front();
                }
            }
            st.ring.push_back(record);
            if st.ring.len() > self.cap {
                st.ring.pop_front();
                evicted += 1;
            }
        }
        if evicted > 0 {
            self.dropped.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Snapshot of the recent-trace ring, oldest first.
    pub fn records(&self) -> Vec<TraceRecord> {
        let st = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        st.ring.iter().cloned().collect()
    }

    /// The exemplar set: slowest traces first, then any retained
    /// failed traces not already among them.
    pub fn exemplars(&self) -> Vec<TraceRecord> {
        let st = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        let mut out: Vec<TraceRecord> = st.slow.clone();
        let mut seen: Vec<u64> = out.iter().map(|r| r.seq).collect();
        for r in &st.failed {
            if !seen.contains(&r.seq) {
                seen.push(r.seq);
                out.push(r.clone());
            }
        }
        out
    }

    /// Everything the recorder still holds (ring + exemplars,
    /// deduplicated), by commit order — the `serve --trace PATH`
    /// export set.
    pub fn all_records(&self) -> Vec<TraceRecord> {
        let mut out = self.records();
        let mut seen: Vec<u64> = out.iter().map(|r| r.seq).collect();
        for r in self.exemplars() {
            if !seen.contains(&r.seq) {
                seen.push(r.seq);
                out.push(r);
            }
        }
        out.sort_by_key(|r| r.seq);
        out
    }

    /// Per-shard share of recorded span time by phase:
    /// `(shard, [(phase, micros, share)])`, phases largest first.
    /// Nested spans (pack/verify inside execute, backoff inside
    /// retry) each count their own wall time, so shares describe
    /// where time is attributable, not a partition of it.
    pub fn phase_shares(&self) -> Vec<(String, Vec<(&'static str, u64, f64)>)> {
        let st = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        let mut by_shard: BTreeMap<&String, Vec<(&'static str, u64)>> = BTreeMap::new();
        for ((shard, phase), micros) in st.phases.iter() {
            by_shard.entry(shard).or_default().push((phase, *micros));
        }
        let mut out = Vec::new();
        for (shard, mut phases) in by_shard {
            phases.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
            let total: u64 = phases.iter().map(|p| p.1).sum();
            let total = total.max(1) as f64;
            let shares = phases
                .into_iter()
                .map(|(phase, us)| (phase, us, us as f64 / total))
                .collect();
            out.push((shard.clone(), shares));
        }
        out
    }

    /// One-line phase breakdown for `Serve::summary()`, e.g.
    /// `native:threadpool execute 78% queue 15% verify 4%`.
    pub fn phase_summary(&self) -> String {
        let mut lines = Vec::new();
        for (shard, phases) in self.phase_shares() {
            let label = if shard.is_empty() { "(unrouted)" } else { &shard };
            let mut line = label.to_string();
            for (phase, _us, share) in phases {
                line.push_str(&format!(" {phase} {:.0}%", 100.0 * share));
            }
            lines.push(line);
        }
        lines.join("; ")
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

fn chrome_event(name: &str, ts: u64, dur: u64, tid: u64, args: &Attrs) -> String {
    // Attributes may be attached more than once (a retried request
    // can hit several fault sites); a JSON object must not repeat a
    // key, so the LAST attachment wins — matching "most recent state"
    // semantics everywhere the export is read.
    let mut fields: Vec<(&str, String)> = Vec::with_capacity(args.len());
    for (k, v) in args {
        let rendered =
            format!("\"{}\":\"{}\"", json_escape(k), json_escape(v));
        match fields.iter_mut().find(|(fk, _)| fk == k) {
            Some((_, slot)) => *slot = rendered,
            None => fields.push((k, rendered)),
        }
    }
    let fields: Vec<String> =
        fields.into_iter().map(|(_, f)| f).collect();
    format!(
        "{{\"name\":\"{}\",\"cat\":\"serve\",\"ph\":\"X\",\
         \"ts\":{ts},\"dur\":{dur},\"pid\":1,\"tid\":{tid},\
         \"args\":{{{}}}}}",
        json_escape(name),
        fields.join(","))
}

/// Render records as Chrome trace-event JSON (`ph: "X"` complete
/// events, microsecond timestamps) loadable in `chrome://tracing` or
/// Perfetto. Each trace id gets its own `tid` lane; every record
/// emits a `request` envelope event carrying trace-level attributes
/// plus one event per span.
pub fn chrome_trace(records: &[TraceRecord]) -> String {
    let mut events = Vec::new();
    for r in records {
        let mut args: Attrs = vec![
            ("outcome", r.outcome.to_string()),
            ("kernel", r.kernel.clone()),
        ];
        if !r.shard.is_empty() {
            args.push(("shard", r.shard.clone()));
        }
        if let Some(sid) = r.session {
            args.push(("session", sid.to_string()));
        }
        args.extend(r.attrs.iter().cloned());
        events.push(chrome_event("request", r.start_us, r.total_us(), r.id, &args));
        for s in &r.spans {
            events.push(chrome_event(
                &s.kind.label(),
                s.start_us,
                s.micros(),
                r.id,
                &s.attrs,
            ));
        }
    }
    format!("{{\"traceEvents\":[{}]}}", events.join(",\n"))
}

/// Intern an attribute key parsed back from JSON. [`Attrs`] keys are
/// `&'static str` because live instrumentation uses a closed, static
/// vocabulary; reloaded keys come from the same vocabulary, so the
/// leak is bounded by it (and deduplicated per parse call).
fn intern_key(seen: &mut BTreeMap<String, &'static str>, key: &str)
              -> &'static str {
    if let Some(k) = seen.get(key) {
        return k;
    }
    let leaked: &'static str =
        Box::leak(key.to_string().into_boxed_str());
    seen.insert(key.to_string(), leaked);
    leaked
}

/// Reload records from [`chrome_trace`] output — the `alpaka-bench
/// trace` subcommand's input path. Tolerant of foreign trace-event
/// JSON: events that are not this module's `request` envelopes or
/// span names are skipped, and a span with no preceding envelope on
/// its lane is dropped rather than erroring.
pub fn parse_chrome_trace(text: &str) -> Result<Vec<TraceRecord>, String> {
    use crate::util::json::{self, Value};

    let doc = json::parse(text).map_err(|e| e.to_string())?;
    let events = doc
        .get("traceEvents")
        .and_then(Value::as_array)
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let mut keys: BTreeMap<String, &'static str> = BTreeMap::new();
    let mut records: Vec<TraceRecord> = Vec::new();
    // The export writes each envelope immediately before its spans,
    // so a span belongs to the latest envelope seen on its tid lane.
    let mut lane: BTreeMap<u64, usize> = BTreeMap::new();
    for ev in events {
        let Some(name) = ev.get("name").and_then(Value::as_str) else {
            continue;
        };
        let Some(ts) = ev.get("ts").and_then(Value::as_u64) else {
            continue;
        };
        let Some(tid) = ev.get("tid").and_then(Value::as_u64) else {
            continue;
        };
        let dur = ev.get("dur").and_then(Value::as_u64).unwrap_or(0);
        let args = match ev.get("args") {
            Some(Value::Object(m)) => m
                .iter()
                .filter_map(|(k, v)| {
                    v.as_str().map(|s| (k.as_str(), s.to_string()))
                })
                .collect::<Vec<_>>(),
            _ => Vec::new(),
        };
        if name == "request" {
            let mut rec = TraceRecord {
                id: tid,
                seq: records.len() as u64 + 1,
                kernel: String::new(),
                session: None,
                outcome: "ok",
                shard: String::new(),
                start_us: ts,
                end_us: ts + dur,
                spans: Vec::new(),
                attrs: Vec::new(),
            };
            for (k, v) in args {
                match k {
                    "kernel" => rec.kernel = v,
                    "shard" => rec.shard = v,
                    "session" => rec.session = v.parse().ok(),
                    "outcome" => {
                        rec.outcome = intern_key(&mut keys, &v);
                    }
                    other => {
                        rec.attrs
                            .push((intern_key(&mut keys, other), v));
                    }
                }
            }
            lane.insert(tid, records.len());
            records.push(rec);
        } else if let Some(kind) = SpanKind::parse(name) {
            let Some(&at) = lane.get(&tid) else {
                continue; // span with no envelope: foreign JSON
            };
            records[at].spans.push(Span {
                kind,
                start_us: ts,
                end_us: ts + dur,
                attrs: args
                    .into_iter()
                    .map(|(k, v)| (intern_key(&mut keys, k), v))
                    .collect(),
            });
        }
    }
    for rec in &mut records {
        rec.spans.sort_by(|a, b| {
            a.start_us
                .cmp(&b.start_us)
                .then(b.end_us.cmp(&a.end_us))
        });
    }
    Ok(records)
}

fn fmt_ms(us: u64) -> String {
    format!("{:.2}ms", us as f64 / 1000.0)
}

/// Render a text waterfall of the `top` slowest records: one header
/// line per trace, one bar-chart line per span with offset, duration
/// and attributes — the terminal-friendly view of the same data the
/// Chrome export carries.
pub fn waterfall(records: &[TraceRecord], top: usize) -> String {
    const WIDTH: u64 = 32;
    let mut sorted: Vec<&TraceRecord> = records.iter().collect();
    sorted.sort_by(|a, b| b.total_us().cmp(&a.total_us()).then(a.seq.cmp(&b.seq)));
    let mut out = String::new();
    for r in sorted.iter().take(top) {
        let shard = if r.shard.is_empty() { "-" } else { &r.shard };
        let attrs: Vec<String> = r
            .attrs
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        out.push_str(&format!(
            "trace {} {} [{}] {} {} {}\n",
            r.id,
            r.kernel,
            shard,
            r.outcome,
            fmt_ms(r.total_us()),
            attrs.join(" ")));
        let total = r.total_us().max(1);
        for s in &r.spans {
            let off = s.start_us.saturating_sub(r.start_us).min(total);
            let cells = (off * WIDTH / total).min(WIDTH - 1);
            let len = (s.micros() * WIDTH).div_ceil(total).max(1);
            let len = len.min(WIDTH - cells);
            let mut bar = " ".repeat(cells as usize);
            bar.push_str(&"#".repeat(len as usize));
            bar.push_str(&" ".repeat((WIDTH - cells - len) as usize));
            let attrs: Vec<String> = s
                .attrs
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect();
            out.push_str(&format!(
                "  {:<12} |{bar}| +{:<9} {:<9} {}\n",
                s.kind.label(),
                fmt_ms(off),
                fmt_ms(s.micros()),
                attrs.join(" ")));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq_hint: u64, total_us: u64, outcome: &'static str) -> TraceRecord {
        TraceRecord {
            id: seq_hint,
            seq: 0,
            kernel: format!("k{seq_hint}"),
            session: None,
            outcome,
            shard: "sim:knl".to_string(),
            start_us: 0,
            end_us: total_us,
            spans: vec![Span {
                kind: SpanKind::Execute,
                start_us: 0,
                end_us: total_us,
                attrs: Vec::new(),
            }],
            attrs: Vec::new(),
        }
    }

    #[test]
    fn guard_records_span_with_attrs_on_drop() {
        let recorder = Arc::new(TraceRecorder::new(8, 2));
        let trace = recorder.begin(1, "k".to_string(), Some(7));
        {
            let mut g = trace.span(SpanKind::Execute);
            g.attr("shard", "sim:knl");
            g.fault(FaultSite::CorruptOutput);
            g.fail(&ServeError::Backend("boom".to_string()));
        }
        trace.finish(&Err(ServeError::Backend("boom".to_string())));
        let records = recorder.records();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        assert_eq!(r.outcome, "backend");
        assert_eq!(r.session, Some(7));
        // synthesized queue span first, then the execute span
        assert_eq!(r.spans[0].kind, SpanKind::Queue);
        let exec = &r.spans[1];
        assert_eq!(exec.kind, SpanKind::Execute);
        assert!(exec.end_us >= exec.start_us);
        assert_eq!(exec.attr("shard"), Some("sim:knl"));
        assert_eq!(exec.attr("fault"), Some("corrupt-output"));
        assert_eq!(exec.attr("error"), Some("backend"));
    }

    #[test]
    fn finish_commits_exactly_once() {
        let recorder = Arc::new(TraceRecorder::new(8, 0));
        let trace = recorder.begin(1, "k".to_string(), None);
        let err = Err(ServeError::Closed);
        trace.finish(&err);
        trace.finish(&err);
        assert_eq!(recorder.committed(), 1);
        assert_eq!(recorder.records().len(), 1);
    }

    #[test]
    fn spans_after_commit_are_ignored() {
        let recorder = Arc::new(TraceRecorder::new(8, 0));
        let trace = recorder.begin(1, "k".to_string(), None);
        trace.finish(&Err(ServeError::Closed));
        let g = trace.span(SpanKind::Execute);
        g.end();
        trace.record(SpanKind::Batch, 0, Vec::new());
        // queue synthesized at commit is the only span
        assert_eq!(recorder.records()[0].spans.len(), 1);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let recorder = TraceRecorder::new(2, 0);
        for i in 1..=5 {
            recorder.commit(rec(i, 10 * i, "ok"));
        }
        assert_eq!(recorder.committed(), 5);
        assert_eq!(recorder.dropped(), 3);
        let ids: Vec<u64> = recorder.records().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![4, 5]);
    }

    #[test]
    fn exemplars_keep_slowest_and_failed_past_overflow() {
        let recorder = TraceRecorder::new(2, 2);
        recorder.commit(rec(1, 900, "ok"));
        recorder.commit(rec(2, 50, "corrupted"));
        recorder.commit(rec(3, 500, "ok"));
        recorder.commit(rec(4, 10, "ok"));
        recorder.commit(rec(5, 20, "ok"));
        // ring holds only 4 and 5, but the slow exemplars kept the
        // two slowest and the failed list kept the corrupted trace
        let ex = recorder.exemplars();
        let ids: Vec<u64> = ex.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3, 2]);
        let all = recorder.all_records();
        assert_eq!(all.len(), 5);
        assert!(all.windows(2).all(|w| w[0].seq < w[1].seq));
    }

    #[test]
    fn queue_span_covers_submission_to_first_stage() {
        let recorder = Arc::new(TraceRecorder::new(4, 0));
        let trace = recorder.begin(9, "k".to_string(), None);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let g = trace.span(SpanKind::Execute);
        g.end();
        trace.finish(&Err(ServeError::Cancelled));
        let r = &recorder.records()[0];
        let queue = &r.spans[0];
        assert_eq!(queue.kind, SpanKind::Queue);
        assert_eq!(queue.start_us, r.start_us);
        assert_eq!(queue.end_us, r.spans[1].start_us);
        assert!(queue.micros() >= 1000);
    }

    #[test]
    fn phase_shares_fold_per_shard() {
        let recorder = TraceRecorder::new(8, 0);
        let mut r = rec(1, 100, "ok");
        r.spans.push(Span {
            kind: SpanKind::Retry(1),
            start_us: 0,
            end_us: 25,
            attrs: Vec::new(),
        });
        r.spans.push(Span {
            kind: SpanKind::Retry(2),
            start_us: 25,
            end_us: 50,
            attrs: Vec::new(),
        });
        recorder.commit(r);
        let shares = recorder.phase_shares();
        assert_eq!(shares.len(), 1);
        let (shard, phases) = &shares[0];
        assert_eq!(shard, "sim:knl");
        // execute 100us, retry#1 + retry#2 folded into retry 50us
        assert_eq!(phases[0], ("execute", 100, 100.0 / 150.0));
        assert_eq!(phases[1], ("retry", 50, 50.0 / 150.0));
        let line = recorder.phase_summary();
        assert!(line.contains("sim:knl"), "{line}");
        assert!(line.contains("execute 67%"), "{line}");
    }

    #[test]
    fn chrome_export_shape_and_escaping() {
        let mut r = rec(3, 40, "ok");
        r.kernel = "k\"quote\\".to_string();
        r.session = Some(2);
        r.spans[0].attrs.push(("note", "tab\there".to_string()));
        let json = chrome_trace(&[r]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"name\":\"request\""));
        assert!(json.contains("\"name\":\"execute\""));
        assert!(json.contains("\"tid\":3"));
        assert!(json.contains("\"session\":\"2\""));
        assert!(json.contains("k\\\"quote\\\\"));
        assert!(json.contains("tab\\there"));
    }

    #[test]
    fn waterfall_renders_slowest_first() {
        let records = vec![rec(1, 100, "ok"), rec(2, 900, "corrupted")];
        let text = waterfall(&records, 1);
        assert!(text.contains("trace 2"), "{text}");
        assert!(!text.contains("trace 1"), "{text}");
        assert!(text.contains("execute"), "{text}");
        assert!(text.contains("corrupted"), "{text}");
    }

    #[test]
    fn chrome_export_round_trips_through_parse() {
        let mut r1 = rec(7, 120, "corrupted");
        r1.session = Some(3);
        r1.attrs.push(("error", "corrupted".to_string()));
        r1.spans[0].attrs.push(("attempt", "1".to_string()));
        r1.spans.push(Span {
            kind: SpanKind::Retry(1),
            start_us: 40,
            end_us: 120,
            attrs: vec![("delay_us", "10".to_string())],
        });
        let r2 = rec(8, 60, "ok");
        let json = chrome_trace(&[r1, r2]);
        let back = parse_chrome_trace(&json).unwrap();
        assert_eq!(back.len(), 2);
        let b1 = &back[0];
        assert_eq!((b1.id, b1.seq), (7, 1));
        assert_eq!(b1.kernel, "k7");
        assert_eq!(b1.session, Some(3));
        assert_eq!(b1.outcome, "corrupted");
        assert_eq!(b1.shard, "sim:knl");
        assert_eq!(b1.total_us(), 120);
        assert_eq!(b1.attrs, vec![("error", "corrupted".to_string())]);
        assert_eq!(b1.spans.len(), 2);
        assert_eq!(b1.spans[0].kind, SpanKind::Execute);
        assert_eq!(b1.spans[0].attr("attempt"), Some("1"));
        assert_eq!(b1.spans[1].kind, SpanKind::Retry(1));
        assert_eq!(b1.spans[1].attr("delay_us"), Some("10"));
        assert_eq!(back[1].outcome, "ok");
        // the reloaded records render in the same waterfall
        let text = waterfall(&back, 2);
        assert!(text.contains("trace 7") && text.contains("retry#1"),
                "{text}");
    }

    #[test]
    fn parse_chrome_trace_rejects_garbage() {
        assert!(parse_chrome_trace("not json").is_err());
        assert!(parse_chrome_trace("{\"other\":1}").is_err());
        // valid but foreign trace JSON: tolerated, yields no records
        let foreign = "{\"traceEvents\":[{\"name\":\"gpu\",\
                       \"ph\":\"X\",\"ts\":1,\"dur\":2,\"pid\":1,\
                       \"tid\":9}]}";
        assert_eq!(parse_chrome_trace(foreign).unwrap().len(), 0);
    }

    #[test]
    fn span_kind_labels_round_trip() {
        let kinds = [
            SpanKind::Queue,
            SpanKind::Route,
            SpanKind::Batch,
            SpanKind::Pack,
            SpanKind::Execute,
            SpanKind::Verify,
            SpanKind::Retry(3),
            SpanKind::Backoff,
            SpanKind::CacheMem,
            SpanKind::CacheDisk,
            SpanKind::TuneExplore,
            SpanKind::Model,
        ];
        for kind in kinds {
            assert_eq!(SpanKind::parse(&kind.label()), Some(kind));
        }
        assert_eq!(SpanKind::parse("retry#7").unwrap(), SpanKind::Retry(7));
        assert_eq!(SpanKind::parse("nope"), None);
    }

    #[test]
    fn error_variants_are_stable() {
        assert_eq!(error_variant(&ServeError::Closed), "closed");
        assert_eq!(error_variant(&ServeError::Cancelled), "cancelled");
        assert_eq!(
            error_variant(&ServeError::Backend(String::new())),
            "backend"
        );
    }
}
