//! Deterministic fault-injection plane + the recovery policies layered
//! on top of it.
//!
//! The serve layer's overload semantics are exact and machine-checked;
//! this module gives its *failure* semantics the same treatment. One
//! mechanism — a seeded [`FaultPlan`] with named injection sites, each
//! with an independent probability drawn from [`crate::util::prng`] —
//! and separate policies: a budgeted [`RetryPolicy`] for idempotent
//! work, and an artifact circuit breaker ([`Quarantine`], configured by
//! [`QuarantinePolicy`]) that isolates poison artifacts after K
//! consecutive post-retry failures.
//!
//! # Replayability
//!
//! Every injection site draws from its **own** serialized
//! `SplitMix64` stream, seeded by mixing the plan seed with the site
//! index. Two runs with the same seed therefore see the same per-site
//! random sequence; when the request schedule is deterministic (a
//! sequential closed loop), the fault assignment is bit-identical —
//! the `chaos_serve` bench asserts exactly this. Under concurrent
//! workers the per-site draw *sequence* is still fixed (the stream is
//! shared and serialized); only which request lands on which draw can
//! vary with thread interleaving.
//!
//! All work routed through the serve layer is idempotent — a request
//! names a pure computation (a simulated prediction, a deterministic
//! PRNG-seeded GEMM, a bounded exploration that re-checks the store
//! before committing) — which is what makes blanket retry of
//! `Backend`/`Corrupted` failures sound. `Overloaded` and `Closed` are
//! *admission* outcomes, not execution failures, and are never retried:
//! retrying them would amplify exactly the load that caused them.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use super::trace::ActiveTrace;
use crate::util::prng::SplitMix64;

/// A named place in the serve layer where a [`FaultPlan`] can inject a
/// failure. Each site has an independent probability and PRNG stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// The backend returns a compute error instead of running.
    BackendError,
    /// The threadpool backend's output is perturbed *before* its
    /// oracle digest check, which must then trip (exercising the real
    /// corruption-detection machinery, not a shortcut).
    CorruptOutput,
    /// The shard worker panics mid-request (caught by supervision,
    /// backend respawned, the in-flight reply preserved).
    WorkerPanic,
    /// The shard worker stalls for [`FaultPlan::stall`] before
    /// replying (exercises deadline-aware session close).
    StallReply,
    /// A disk-cache probe fails as if the read I/O failed (must
    /// degrade to a counted miss, never an error to the caller).
    DiskCacheRead,
    /// A disk-cache spill fails as if the write I/O failed (must
    /// leave no partial file and keep the cache usable).
    DiskCacheWrite,
    /// The tuner shard fails to commit an exploration result.
    TunerCommit,
}

impl FaultSite {
    /// Every site, in stable order (the index order of the plan's
    /// per-site streams and counters).
    pub const ALL: [FaultSite; 7] = [
        FaultSite::BackendError,
        FaultSite::CorruptOutput,
        FaultSite::WorkerPanic,
        FaultSite::StallReply,
        FaultSite::DiskCacheRead,
        FaultSite::DiskCacheWrite,
        FaultSite::TunerCommit,
    ];

    pub fn index(self) -> usize {
        match self {
            FaultSite::BackendError => 0,
            FaultSite::CorruptOutput => 1,
            FaultSite::WorkerPanic => 2,
            FaultSite::StallReply => 3,
            FaultSite::DiskCacheRead => 4,
            FaultSite::DiskCacheWrite => 5,
            FaultSite::TunerCommit => 6,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            FaultSite::BackendError => "backend-error",
            FaultSite::CorruptOutput => "corrupt-output",
            FaultSite::WorkerPanic => "worker-panic",
            FaultSite::StallReply => "stall-reply",
            FaultSite::DiskCacheRead => "disk-read",
            FaultSite::DiskCacheWrite => "disk-write",
            FaultSite::TunerCommit => "tuner-commit",
        }
    }
}

const SITES: usize = FaultSite::ALL.len();

/// A seeded, replayable chaos schedule: per-site probabilities plus
/// per-site PRNG streams and fired/drawn counters. Thread one through
/// [`ServeConfig::fault_plan`](super::ServeConfig) to turn a serve
/// layer into a chaos testbed; leave it `None` (the default) and every
/// injection site compiles down to a cheap `None` check.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    rates: [f64; SITES],
    stall: Duration,
    streams: [Mutex<SplitMix64>; SITES],
    drawn: [AtomicU64; SITES],
    fired: [AtomicU64; SITES],
}

impl FaultPlan {
    /// A plan with every site at probability 0 (inert until rates are
    /// set with [`FaultPlan::with_rate`]).
    pub fn new(seed: u64) -> Self {
        // Site streams are decorrelated from each other and from the
        // plan seed by a golden-ratio odd-multiplier mix (the same
        // finalizer family SplitMix64 itself uses).
        let streams = std::array::from_fn(|i| {
            let mixed = seed
                ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(i as u64 + 1);
            Mutex::new(SplitMix64::new(mixed))
        });
        Self {
            seed,
            rates: [0.0; SITES],
            stall: Duration::from_millis(50),
            streams,
            drawn: std::array::from_fn(|_| AtomicU64::new(0)),
            fired: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// The canonical chaos mix used by the bench and the CLI: backend
    /// errors at `rate`, output corruption and worker panics at half
    /// of it, everything else quiet.
    pub fn chaos(seed: u64, rate: f64) -> Self {
        Self::new(seed)
            .with_rate(FaultSite::BackendError, rate)
            .with_rate(FaultSite::CorruptOutput, rate / 2.0)
            .with_rate(FaultSite::WorkerPanic, rate / 2.0)
    }

    /// Set one site's firing probability (clamped to `[0, 1]`).
    pub fn with_rate(mut self, site: FaultSite, p: f64) -> Self {
        self.rates[site.index()] = p.clamp(0.0, 1.0);
        self
    }

    /// Set the stall duration used when [`FaultSite::StallReply`]
    /// fires.
    pub fn with_stall(mut self, stall: Duration) -> Self {
        self.stall = stall;
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn rate(&self, site: FaultSite) -> f64 {
        self.rates[site.index()]
    }

    pub fn stall(&self) -> Duration {
        self.stall
    }

    /// Draw from `site`'s stream: `true` means the fault fires. Every
    /// call with a nonzero rate advances the site's stream and bumps
    /// its drawn counter, so `(drawn, fired)` pairs fully describe a
    /// run for replay comparison.
    pub fn should_fire(&self, site: FaultSite) -> bool {
        let i = site.index();
        let rate = self.rates[i];
        if rate <= 0.0 {
            return false;
        }
        self.drawn[i].fetch_add(1, Ordering::Relaxed);
        let hit = {
            let mut g = self.streams[i]
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            g.next_unit() < rate
        };
        if hit {
            self.fired[i].fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// [`should_fire`](Self::should_fire), annotating the active
    /// trace with `fault=<site>` when the draw hits. Used by sites
    /// that have no span of their own open at the draw point (disk
    /// cache I/O, stalled replies): the fault still shows up on the
    /// request's trace even though it fired between spans.
    pub fn should_fire_traced(&self, site: FaultSite,
                              trace: Option<&Arc<ActiveTrace>>) -> bool {
        let hit = self.should_fire(site);
        match trace {
            Some(t) if hit => t.attach("fault", site.label()),
            _ => {}
        }
        hit
    }

    /// How many times `site` was consulted.
    pub fn drawn(&self, site: FaultSite) -> u64 {
        self.drawn[site.index()].load(Ordering::Relaxed)
    }

    /// How many times `site` actually fired.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.fired[site.index()].load(Ordering::Relaxed)
    }

    /// `(label, drawn, fired)` for every site — the replayability
    /// fingerprint of a run.
    pub fn site_counts(&self) -> Vec<(&'static str, u64, u64)> {
        FaultSite::ALL
            .iter()
            .map(|s| (s.label(), self.drawn(*s), self.fired(*s)))
            .collect()
    }
}

/// Budgeted retry for idempotent work, applied by shard workers to
/// `Backend`/`Corrupted` execution failures (and caught worker
/// panics) — never to `Overloaded`/`Closed`, which are admission
/// outcomes (see the module docs for the idempotency argument).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total execution attempts per request, including the first
    /// (clamped to at least 1; 1 = no retry, the default).
    pub max_attempts: u32,
    /// Base delay before attempt `k+1` (scaled linearly by the attempt
    /// number).
    pub backoff: Duration,
    /// Fraction of the backoff randomized per retry, in `[0, 1]`
    /// (drawn from a per-worker deterministic stream).
    pub jitter: f64,
}

impl RetryPolicy {
    /// `max_attempts` with the ≥ 1 clamp applied.
    pub fn attempts(&self) -> u32 {
        self.max_attempts.max(1)
    }

    /// The delay before attempt `next_attempt` (1-based), jittered by
    /// `unit` (a `[0, 1)` draw).
    pub fn delay(&self, next_attempt: u32, unit: f64) -> Duration {
        let base = self.backoff.as_secs_f64()
            * next_attempt.saturating_sub(1).max(1) as f64;
        let jitter = self.jitter.clamp(0.0, 1.0);
        let scale = 1.0 + jitter * (unit.clamp(0.0, 1.0) - 0.5);
        Duration::from_secs_f64(base * scale.max(0.0))
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 1,
            backoff: Duration::from_millis(1),
            jitter: 0.5,
        }
    }
}

/// Circuit-breaker policy for poison artifacts. `threshold` 0 (the
/// default) disables quarantine entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuarantinePolicy {
    /// Consecutive post-retry execution failures of one artifact that
    /// trip its breaker open.
    pub threshold: u32,
    /// How long the breaker stays open before a half-open probe is
    /// admitted to re-validate the artifact.
    pub cooldown: Duration,
}

impl Default for QuarantinePolicy {
    fn default() -> Self {
        Self { threshold: 0, cooldown: Duration::from_millis(250) }
    }
}

/// What the quarantine gate says about an artifact at admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Not quarantined: route normally.
    Allow,
    /// The breaker's cooldown elapsed: this single request is the
    /// half-open probe that re-validates the artifact.
    Probe,
    /// Quarantined (or a probe is already in flight): fail fast.
    Deny,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BreakerState {
    Closed,
    Open { until: Instant },
    HalfOpen,
}

#[derive(Debug)]
struct BreakerEntry {
    consecutive: u32,
    state: BreakerState,
}

/// The artifact circuit breaker, keyed by artifact identity digest
/// (one entry per distinct artifact content, shared across shards).
///
/// State machine per key:
///
/// ```text
/// Closed ──K consecutive post-retry failures──▶ Open(until)
/// Open(until) ──request before `until`──▶ deny (fail fast)
/// Open(until) ──first request after `until`──▶ HalfOpen (that
///                request is the probe; others still denied)
/// HalfOpen ──probe Ok──▶ entry removed (re-validated)
/// HalfOpen ──probe Err──▶ Open(now + cooldown)
/// ```
#[derive(Debug)]
pub struct Quarantine {
    policy: QuarantinePolicy,
    entries: Mutex<BTreeMap<String, BreakerEntry>>,
}

impl Quarantine {
    pub fn new(policy: QuarantinePolicy) -> Self {
        Self { policy, entries: Mutex::new(BTreeMap::new()) }
    }

    pub fn policy(&self) -> QuarantinePolicy {
        self.policy
    }

    fn guard(&self)
             -> std::sync::MutexGuard<'_, BTreeMap<String, BreakerEntry>>
    {
        self.entries.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Gate one request for `key` (called by the dispatcher before
    /// routing).
    pub fn admit(&self, key: &str) -> Admission {
        let mut g = self.guard();
        match g.get_mut(key) {
            None => Admission::Allow,
            Some(e) => match e.state {
                BreakerState::Closed => Admission::Allow,
                BreakerState::Open { until } => {
                    if Instant::now() >= until {
                        e.state = BreakerState::HalfOpen;
                        Admission::Probe
                    } else {
                        Admission::Deny
                    }
                }
                BreakerState::HalfOpen => Admission::Deny,
            },
        }
    }

    /// Record a post-retry execution failure for `key`. Returns `true`
    /// when this failure tripped the breaker open (the caller counts a
    /// quarantine entry).
    pub fn record_failure(&self, key: &str) -> bool {
        let mut g = self.guard();
        let e = g.entry(key.to_string()).or_insert(BreakerEntry {
            consecutive: 0,
            state: BreakerState::Closed,
        });
        e.consecutive = e.consecutive.saturating_add(1);
        match e.state {
            BreakerState::HalfOpen => {
                // the probe failed: straight back to open
                e.state = BreakerState::Open {
                    until: Instant::now() + self.policy.cooldown,
                };
                true
            }
            BreakerState::Closed => {
                if e.consecutive >= self.policy.threshold.max(1) {
                    e.state = BreakerState::Open {
                        until: Instant::now() + self.policy.cooldown,
                    };
                    true
                } else {
                    false
                }
            }
            // stragglers already past admission when the breaker
            // tripped: the breaker is already open, nothing new
            BreakerState::Open { .. } => false,
        }
    }

    /// Record a successful execution for `key`. Returns `true` when
    /// this success closed an open breaker (the probe re-validated the
    /// artifact; the caller counts a quarantine exit).
    pub fn record_success(&self, key: &str) -> bool {
        let mut g = self.guard();
        match g.get_mut(key) {
            None => false,
            Some(e) => match e.state {
                BreakerState::HalfOpen => {
                    g.remove(key);
                    true
                }
                BreakerState::Closed => {
                    e.consecutive = 0;
                    false
                }
                // a pre-quarantine straggler succeeding does not
                // re-validate: only the half-open probe may close
                BreakerState::Open { .. } => false,
            },
        }
    }

    /// `(key, state label, consecutive failures)` for every tracked
    /// artifact — the bench's attribution evidence.
    pub fn snapshot(&self) -> Vec<(String, &'static str, u32)> {
        self.guard()
            .iter()
            .map(|(k, e)| {
                let s = match e.state {
                    BreakerState::Closed => "closed",
                    BreakerState::Open { .. } => "open",
                    BreakerState::HalfOpen => "half-open",
                };
                (k.clone(), s, e.consecutive)
            })
            .collect()
    }

    /// Keys currently quarantined (open or half-open).
    pub fn quarantined(&self) -> Vec<String> {
        self.guard()
            .iter()
            .filter(|(_, e)| {
                !matches!(e.state, BreakerState::Closed)
            })
            .map(|(k, _)| k.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_never_fires_and_never_draws() {
        let plan = FaultPlan::new(7);
        for _ in 0..100 {
            assert!(!plan.should_fire(FaultSite::BackendError));
        }
        assert_eq!(plan.drawn(FaultSite::BackendError), 0);
        assert_eq!(plan.fired(FaultSite::BackendError), 0);
    }

    #[test]
    fn unit_rate_always_fires() {
        let plan = FaultPlan::new(7)
            .with_rate(FaultSite::WorkerPanic, 1.0);
        for _ in 0..50 {
            assert!(plan.should_fire(FaultSite::WorkerPanic));
        }
        assert_eq!(plan.drawn(FaultSite::WorkerPanic), 50);
        assert_eq!(plan.fired(FaultSite::WorkerPanic), 50);
    }

    #[test]
    fn same_seed_replays_the_same_fault_schedule() {
        let mk = || FaultPlan::chaos(0xC0FFEE, 0.3);
        let a = mk();
        let b = mk();
        let mut seq_a = Vec::new();
        let mut seq_b = Vec::new();
        for _ in 0..200 {
            for site in FaultSite::ALL {
                seq_a.push(a.should_fire(site));
                seq_b.push(b.should_fire(site));
            }
        }
        assert_eq!(seq_a, seq_b, "same seed, same schedule");
        assert_eq!(a.site_counts(), b.site_counts());
        // and a different seed produces a different schedule
        let c = FaultPlan::chaos(0xC0FFEE + 1, 0.3);
        let seq_c: Vec<bool> = (0..200)
            .flat_map(|_| {
                FaultSite::ALL
                    .map(|s| c.should_fire(s))
            })
            .collect();
        assert_ne!(seq_a, seq_c, "seed changes the schedule");
    }

    #[test]
    fn sites_draw_from_independent_streams() {
        // Consuming one site's stream must not shift another's.
        let a = FaultPlan::chaos(42, 0.5);
        let b = FaultPlan::chaos(42, 0.5);
        for _ in 0..100 {
            let _ = a.should_fire(FaultSite::BackendError);
        }
        let fire_a: Vec<bool> = (0..100)
            .map(|_| a.should_fire(FaultSite::CorruptOutput))
            .collect();
        let fire_b: Vec<bool> = (0..100)
            .map(|_| b.should_fire(FaultSite::CorruptOutput))
            .collect();
        assert_eq!(fire_a, fire_b,
                   "corrupt stream unaffected by backend-error draws");
    }

    #[test]
    fn fired_rate_tracks_probability() {
        let plan = FaultPlan::new(1).with_rate(
            FaultSite::BackendError, 0.1);
        for _ in 0..2000 {
            let _ = plan.should_fire(FaultSite::BackendError);
        }
        let fired = plan.fired(FaultSite::BackendError) as f64;
        assert!(fired > 100.0 && fired < 320.0,
                "~10% of 2000 draws, got {fired}");
    }

    #[test]
    fn retry_policy_clamps_and_jitters() {
        let p = RetryPolicy {
            max_attempts: 0,
            backoff: Duration::from_millis(10),
            jitter: 0.5,
        };
        assert_eq!(p.attempts(), 1, "at least one attempt");
        let lo = p.delay(2, 0.0);
        let hi = p.delay(2, 0.999);
        assert!(lo < hi, "jitter spreads the delay: {lo:?} vs {hi:?}");
        assert!(lo >= Duration::from_millis(7));
        assert!(hi <= Duration::from_millis(13));
        let no_jitter = RetryPolicy {
            max_attempts: 3,
            backoff: Duration::from_millis(10),
            jitter: 0.0,
        };
        assert_eq!(no_jitter.delay(2, 0.7),
                   Duration::from_millis(10));
        assert_eq!(no_jitter.delay(3, 0.7),
                   Duration::from_millis(20), "linear backoff");
    }

    #[test]
    fn quarantine_trips_denies_probes_and_revalidates() {
        let q = Quarantine::new(QuarantinePolicy {
            threshold: 2,
            cooldown: Duration::from_millis(20),
        });
        assert_eq!(q.admit("d1"), Admission::Allow);
        assert!(!q.record_failure("d1"), "below threshold");
        assert_eq!(q.admit("d1"), Admission::Allow);
        assert!(q.record_failure("d1"), "threshold trips the breaker");
        assert_eq!(q.admit("d1"), Admission::Deny);
        assert_eq!(q.quarantined(), vec!["d1".to_string()]);
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(q.admit("d1"), Admission::Probe,
                   "cooldown elapsed: one probe admitted");
        assert_eq!(q.admit("d1"), Admission::Deny,
                   "only ONE probe while half-open");
        assert!(q.record_success("d1"), "probe success re-validates");
        assert_eq!(q.admit("d1"), Admission::Allow);
        assert!(q.quarantined().is_empty());
    }

    #[test]
    fn failed_probe_reopens_and_success_resets_consecutive() {
        let q = Quarantine::new(QuarantinePolicy {
            threshold: 1,
            cooldown: Duration::from_millis(10),
        });
        assert!(q.record_failure("d"));
        std::thread::sleep(Duration::from_millis(15));
        assert_eq!(q.admit("d"), Admission::Probe);
        assert!(q.record_failure("d"), "failed probe re-opens");
        assert_eq!(q.admit("d"), Admission::Deny);
        // a healthy artifact's success resets its failure streak
        let q2 = Quarantine::new(QuarantinePolicy {
            threshold: 2,
            cooldown: Duration::from_millis(10),
        });
        assert!(!q2.record_failure("h"));
        assert!(!q2.record_success("h"));
        assert!(!q2.record_failure("h"),
                "streak was reset by the success");
        let snap = q2.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1, "closed");
    }
}
