//! LRU result cache for the serve layer.
//!
//! Keyed by `(backend shard, work item)` — in practice each shard owns
//! one cache instance, so the key is the canonical work-item string and
//! the backend dimension is implicit. Values are complete serve outputs
//! (deterministic for the simulated backends; for the native backend the
//! cache is only enabled by serving-oriented callers, never by the
//! measurement-oriented `GemmService` shim, which must re-execute).
//!
//! Implementation: `HashMap` plus a monotonically increasing use-tick;
//! eviction scans for the minimum tick. Caches here are small (hundreds
//! of entries), so the O(n) eviction is simpler and cheaper than an
//! intrusive list and trivially correct.

use std::collections::HashMap;

#[derive(Debug)]
pub struct LruCache<V> {
    capacity: usize,
    tick: u64,
    entries: HashMap<String, (u64, V)>,
}

impl<V: Clone> LruCache<V> {
    /// `capacity == 0` means "disabled": every lookup misses, nothing is
    /// stored.
    pub fn new(capacity: usize) -> Self {
        Self { capacity, tick: 0, entries: HashMap::new() }
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up and refresh recency.
    pub fn get(&mut self, key: &str) -> Option<V> {
        self.tick += 1;
        let tick = self.tick;
        self.entries.get_mut(key).map(|(t, v)| {
            *t = tick;
            v.clone()
        })
    }

    /// Insert, evicting the least recently used entry when full.
    pub fn put(&mut self, key: String, value: V) {
        if self.capacity == 0 {
            return;
        }
        self.tick += 1;
        if !self.entries.contains_key(&key)
            && self.entries.len() >= self.capacity
        {
            if let Some(oldest) = self
                .entries
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k.clone())
            {
                self.entries.remove(&oldest);
            }
        }
        self.entries.insert(key, (self.tick, value));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_eviction_order() {
        let mut c: LruCache<u32> = LruCache::new(2);
        assert!(c.get("a").is_none());
        c.put("a".into(), 1);
        c.put("b".into(), 2);
        assert_eq!(c.get("a"), Some(1)); // refresh a → b is now LRU
        c.put("c".into(), 3); // evicts b
        assert_eq!(c.get("b"), None);
        assert_eq!(c.get("a"), Some(1));
        assert_eq!(c.get("c"), Some(3));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn reinsert_updates_value_without_eviction() {
        let mut c: LruCache<u32> = LruCache::new(2);
        c.put("a".into(), 1);
        c.put("b".into(), 2);
        c.put("a".into(), 10); // same key: no eviction
        assert_eq!(c.get("a"), Some(10));
        assert_eq!(c.get("b"), Some(2));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c: LruCache<u32> = LruCache::new(0);
        assert!(!c.enabled());
        c.put("a".into(), 1);
        assert!(c.get("a").is_none());
        assert!(c.is_empty());
    }
}
