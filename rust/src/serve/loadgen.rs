//! Load generators for the serve layer, built on the **client plane**
//! (`crate::client`) — every driver here is a [`Session`] user, so the
//! repo has exactly one client-side concurrency idiom:
//!
//! * [`run_closed_loop`] — N sessions, window 1: each client issues its
//!   next request only after the previous reply (the classic
//!   closed-loop model — offered load adapts to service capacity, so
//!   the measured latencies are queueing-honest).
//! * [`run_stream_loop`] — N sessions, window W: each client pipelines
//!   its request list through [`Session::submit_stream`], consuming
//!   replies in completion order (same client threads, W× the in-flight
//!   work — the `client_stream` bench gates the speedup).
//! * [`run_open_loop`] — one unbounded-window session submits at a
//!   fixed rate regardless of completions (the overload driver).
//!
//! Used by the `serve` CLI subcommand, `rust/benches/serve_load.rs`
//! and `rust/benches/client_stream.rs`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::arch::{compiler, ArchId, CompilerId};
use crate::client::{NodeResult, Session, SessionConfig, WindowPolicy};
use crate::model::{ModelPlan, ModelSpec};
use crate::gemm::Precision;
use crate::runtime::artifact::Manifest;
use crate::sim::TuningPoint;
use crate::util::table::Table;

use super::trace::{self, TraceRecorder};
use super::{FaultPlan, NativeConfig, NativeEngine, NativeEngineId,
            Output, QuarantinePolicy, RetryPolicy, Serve, ServeConfig,
            ServeError, ServeReply, WorkItem};

/// The canonical demo artifact set used when no manifest is available
/// (CLI `serve`, `serve_load` bench, `serve_gemm` example).
pub const DEMO_ARTIFACT_IDS: [&str; 3] =
    ["dot_n128_f32", "dot_n256_f32", "gemm_n128_t16_e1_f32"];

/// Decide how the native shard gets its artifacts: a manifest under
/// `dir` when one exists and contains small square gemm/dot artifacts
/// (the mix stays light), otherwise the synthetic host-GEMM catalog
/// over [`DEMO_ARTIFACT_IDS`] — with a stderr note, so a fallback is
/// never silent. Returns the config plus the artifact ids to mix.
/// Selected ids must be **host-capable** (the backends' own predicate):
/// [`default_mix`] routes every id to the threadpool shard too, which
/// can only serve what the host reference GEMM reproduces.
pub fn native_config_or_synthetic(dir: &Path)
                                  -> (NativeConfig, Vec<String>) {
    match Manifest::load(dir) {
        Ok(m) => {
            let ids: Vec<String> = m
                .artifacts
                .iter()
                .filter(|a| a.n.map(|n| n <= 256).unwrap_or(false)
                        && super::backend::meta_host_capable(a))
                .take(4)
                .map(|a| a.id.clone())
                .collect();
            if !ids.is_empty() {
                return (NativeConfig::Artifacts(dir.to_path_buf()), ids);
            }
            eprintln!("note: manifest in {} has no small gemm/dot \
                       artifacts — native shard uses the synthetic \
                       host-GEMM catalog", dir.display());
        }
        Err(_) => {
            eprintln!("note: no manifest in {} — native shard uses the \
                       synthetic host-GEMM catalog", dir.display());
        }
    }
    let ids: Vec<String> =
        DEMO_ARTIFACT_IDS.iter().map(|s| s.to_string()).collect();
    (NativeConfig::Synthetic(ids.clone()), ids)
}

/// Apply the canonical chaos recipe to a serve config — shared by the
/// CLI (`serve --chaos-seed`) and the `chaos_serve` bench so the two
/// drivers can never drift apart: the [`FaultPlan::chaos`] mix at
/// `rate` (backend errors at `rate`, corruption and worker panics at
/// half of it), a budget of `retries` total execution attempts with a
/// short jittered linear backoff, and — when `quarantine_after > 0` —
/// an artifact circuit breaker opening after that many consecutive
/// post-retry failures. Returns the plan `Arc` alongside the config so
/// the driver can render [`fault_report`] after the run (the config
/// keeps its own clone).
pub fn chaos_config(mut cfg: ServeConfig, seed: u64, rate: f64,
                    retries: u32, quarantine_after: u32)
                    -> (ServeConfig, Arc<FaultPlan>) {
    let plan = Arc::new(FaultPlan::chaos(seed, rate));
    cfg.fault_plan = Some(Arc::clone(&plan));
    cfg.retry = RetryPolicy {
        max_attempts: retries,
        backoff: Duration::from_micros(200),
        jitter: 0.5,
    };
    cfg.quarantine = QuarantinePolicy {
        threshold: quarantine_after,
        cooldown: Duration::from_millis(250),
    };
    (cfg, plan)
}

/// Render a chaos run's injected fault activity: one row per
/// [`FaultSite`](super::FaultSite) with its drawn/fired counters —
/// the replay fingerprint ([`FaultPlan::site_counts`]) in table form.
/// Deterministically ordered (site declaration order).
pub fn fault_report(plan: &FaultPlan) -> String {
    let mut t = Table::new(vec!["fault site", "drawn", "fired"])
        .numeric();
    for (label, drawn, fired) in plan.site_counts() {
        t.row(vec![label.to_string(), drawn.to_string(),
                   fired.to_string()]);
    }
    format!("chaos seed {} — injected fault activity:\n{}",
            plan.seed(), t.render())
}

/// Write everything the flight recorder still holds (recent ring +
/// exemplars) as Chrome-trace JSON — the `serve --trace PATH` export.
/// Returns how many traces were written.
pub fn write_chrome_trace(rec: &TraceRecorder, path: &Path)
                          -> std::io::Result<usize> {
    let records = rec.all_records();
    std::fs::write(path, trace::chrome_trace(&records))?;
    Ok(records.len())
}

/// Write only the exemplar set (slowest traces plus retained failed
/// ones) as Chrome-trace JSON — the bounded `TRACE_exemplars.json`
/// artifact the serve and chaos benches upload next to their
/// `BENCH_*.json`. Returns how many traces were written.
pub fn write_trace_exemplars(rec: &TraceRecorder, path: &Path)
                             -> std::io::Result<usize> {
    let records = rec.exemplars();
    std::fs::write(path, trace::chrome_trace(&records))?;
    Ok(records.len())
}

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Requests issued per client.
    pub requests_per_client: usize,
    /// The mixed item set; client `c`'s request `r` is
    /// `items[(c + r) % items.len()]`, so every client cycles the whole
    /// mix from a different phase.
    pub items: Vec<WorkItem>,
}

/// Aggregated outcome of one load run (latency percentiles, throughput
/// and cache hit rate live in [`super::ServeMetrics`]).
#[derive(Debug, Clone, Default)]
pub struct LoadOutcome {
    pub submitted: usize,
    pub ok: usize,
    pub failed: usize,
    /// Requests shed by overload control (`ServeError::Overloaded`) —
    /// counted separately from `failed` because a shed is the layer
    /// *working as configured*, not an error.
    pub shed: usize,
    pub wall_seconds: f64,
    /// Completed requests per shard label.
    pub per_shard: BTreeMap<String, usize>,
    /// Completed native requests per engine ("pjrt" / "host-gemm" /
    /// "threadpool-gemm").
    pub per_engine: BTreeMap<String, usize>,
    /// Completed native requests per kernel label ("pjrt" /
    /// "tuned{mc=..,..}" / "tuned{..}@store" / "naive") — which kernel
    /// actually produced each result, so tuning wins are attributable
    /// in load reports. BTreeMap: iteration (and thus every report
    /// built from it) is sorted by kernel label, stable across runs.
    pub per_kernel: BTreeMap<String, usize>,
    /// Largest coalesced batch any reply reported.
    pub max_batch_seen: usize,
    /// Error strings observed (deduplicated and **sorted** — reply
    /// arrival order is nondeterministic, reports must not be).
    pub errors: Vec<String>,
}

/// Build the standard mixed item set: for every simulated architecture a
/// small tile sweep (t ∈ {16, 32, 64} on CPUs, t ∈ {2, 4} on GPUs), plus
/// the given native artifact ids on **both** named native shards
/// (`native:pjrt` and `native:threadpool`), so a mixed run exercises
/// real multi-shard native routing.
pub fn default_mix(archs: &[ArchId], artifact_ids: &[String], n: u64)
                   -> Vec<WorkItem> {
    let mut items = Vec::new();
    for &arch in archs {
        let comp = compiler::vendor_compiler(arch);
        if comp == CompilerId::Cuda {
            for t in [2u64, 4] {
                items.push(WorkItem::point(TuningPoint::gpu(
                    arch, Precision::F32, n, t)));
            }
        } else {
            for t in [16u64, 32, 64] {
                items.push(WorkItem::point(TuningPoint::cpu(
                    arch, comp, Precision::F64, n, t, 1)));
            }
        }
    }
    for id in artifact_ids {
        items.push(WorkItem::artifact(id.clone()));
        items.push(WorkItem::artifact_on(id.clone(),
                                         NativeEngineId::Threadpool));
    }
    items
}

/// Fold one reply (or error) into a client-local tally.
fn tally(out: &mut LoadOutcome, result: Result<ServeReply, ServeError>) {
    match result {
        Ok(reply) => {
            out.ok += 1;
            *out.per_shard.entry(reply.shard.clone()).or_default() += 1;
            if let Output::Native { engine, kernel, .. } = &reply.output
            {
                *out.per_engine.entry(engine.slug().to_string())
                    .or_default() += 1;
                *out.per_kernel.entry(kernel.clone()).or_default() += 1;
            }
            out.max_batch_seen = out.max_batch_seen
                .max(reply.batch_size);
        }
        Err(ServeError::Overloaded { .. }) => {
            out.shed += 1;
        }
        Err(e) => {
            out.failed += 1;
            let msg = match e {
                ServeError::Backend(m) => m,
                other => other.to_string(),
            };
            if !out.errors.contains(&msg) {
                out.errors.push(msg);
            }
        }
    }
}

/// Merge per-client tallies into one deterministic total.
fn merge(per_client: Vec<LoadOutcome>, wall_seconds: f64)
         -> LoadOutcome {
    let mut total =
        LoadOutcome { wall_seconds, ..Default::default() };
    for c in per_client {
        total.submitted += c.submitted;
        total.ok += c.ok;
        total.failed += c.failed;
        total.shed += c.shed;
        total.max_batch_seen = total.max_batch_seen.max(c.max_batch_seen);
        for (k, v) in c.per_shard {
            *total.per_shard.entry(k).or_default() += v;
        }
        for (k, v) in c.per_engine {
            *total.per_engine.entry(k).or_default() += v;
        }
        for (k, v) in c.per_kernel {
            *total.per_kernel.entry(k).or_default() += v;
        }
        for e in c.errors {
            if !total.errors.contains(&e) {
                total.errors.push(e);
            }
        }
    }
    // Deterministic reports: client-merge order depends on thread
    // timing, so the deduplicated error list is sorted before anyone
    // renders it (diffable across runs, like the BTreeMap tallies).
    total.errors.sort();
    total
}

/// The item a closed/stream-loop client `c` issues as its request `r`:
/// every client cycles the whole mix from a different phase.
fn client_item(spec: &LoadSpec, c: usize, r: usize) -> WorkItem {
    spec.items[(c + r) % spec.items.len()].clone()
}

/// Run the closed loop: one window-1 [`Session`] per client, each
/// issuing its next request only after the previous reply. Blocks
/// until every client finished. Every request is accounted for in
/// `ok + shed + failed == submitted` — the session plane's exact
/// accounting (and the serve layer's explicit-reply contract) means
/// nothing can vanish; the per-session tallies land in
/// `ServeMetrics::session_tallies`.
pub fn run_closed_loop(serve: &Serve, spec: &LoadSpec) -> LoadOutcome {
    run_stream_loop(serve, spec, 1)
}

/// Run the pipelined loop: one [`Session`] per client with an
/// in-flight **window** of `window` requests, the whole per-client
/// request list streamed through [`Session::submit_stream`] and
/// consumed in completion order. `window == 1` IS the classic closed
/// loop. Same client-thread count at any window — the window is the
/// pipelining knob, which is exactly what the `client_stream` bench
/// measures.
pub fn run_stream_loop(serve: &Serve, spec: &LoadSpec, window: usize)
                       -> LoadOutcome {
    assert!(!spec.items.is_empty(), "load mix must not be empty");
    assert!(spec.clients > 0, "need at least one client");
    assert!(window > 0, "need a positive window");
    let t0 = Instant::now();
    let per_client: Vec<LoadOutcome> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..spec.clients)
            .map(|c| {
                scope.spawn(move || {
                    let session = Session::open(serve, SessionConfig {
                        window,
                        on_full: WindowPolicy::Block,
                        ..SessionConfig::default()
                    });
                    let items: Vec<WorkItem> =
                        (0..spec.requests_per_client)
                            .map(|r| client_item(spec, c, r))
                            .collect();
                    let mut out = LoadOutcome::default();
                    // one yield per item — submitted means attempted,
                    // like the pre-session drivers counted it
                    for (_idx, result) in session.submit_stream(items) {
                        out.submitted += 1;
                        tally(&mut out, result);
                    }
                    let stats = session.close();
                    assert!(stats.fully_accounted(),
                            "session accounting leak: {stats:?}");
                    out
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client panicked"))
            .collect()
    });
    merge(per_client, t0.elapsed().as_secs_f64())
}

/// Open-loop overload parameters: requests are issued at a fixed rate
/// regardless of completions (unlike the closed loop, whose offered
/// load adapts to capacity and therefore can never overload anything).
#[derive(Debug, Clone)]
pub struct OverloadSpec {
    /// Target submission rate, requests/second.
    pub rate_rps: f64,
    /// Total requests to issue.
    pub total: usize,
    /// The mixed item set, cycled round-robin.
    pub items: Vec<WorkItem>,
    /// Optional per-request deadline (relative to its submission) —
    /// pair with `ShedPolicy::ShedExpired`.
    pub deadline: Option<Duration>,
}

/// Outcome of one open-loop run. `submitted` counts what the pacing
/// thread actually submitted; the categorized replies must add back up
/// to it (`ok + shed + closed + failed == submitted`) — a reply
/// callback that is dropped unfired breaks the equation and is caught
/// by [`OverloadOutcome::fully_accounted`], which is the whole point.
#[derive(Debug, Clone, Default)]
pub struct OverloadOutcome {
    pub submitted: usize,
    pub ok: usize,
    /// `ServeError::Overloaded` replies (quota or deadline sheds).
    pub shed: usize,
    /// `ServeError::Closed` replies.
    pub closed: usize,
    /// Backend / cancelled errors.
    pub failed: usize,
    pub wall_seconds: f64,
    /// Completed requests per shard label.
    pub per_shard: BTreeMap<String, usize>,
    /// Error strings observed (deduplicated, for diagnostics).
    pub errors: Vec<String>,
}

impl OverloadOutcome {
    /// Every request got exactly one explicit reply.
    pub fn fully_accounted(&self) -> bool {
        self.ok + self.shed + self.closed + self.failed
            == self.submitted
    }
}

/// Measure the sustainable service rate (completed requests per
/// second) with a short closed-loop probe over `items` — the shared
/// "how hard can this layer actually go" yardstick the overload
/// drivers (CLI `serve --overload` and the `serve_load` bench) multiply
/// to build their offered rate, so the two can never drift apart.
pub fn measure_sustainable_rps(serve: &Serve, items: &[WorkItem],
                               clients: usize,
                               requests_per_client: usize) -> f64 {
    let probe = run_closed_loop(serve, &LoadSpec {
        clients,
        requests_per_client,
        items: items.to_vec(),
    });
    probe.ok as f64 / probe.wall_seconds.max(1e-6)
}

/// Drive the serve layer open-loop: one pacing thread submits
/// `spec.total` requests at `spec.rate_rps` (never waiting for
/// replies), while this thread tallies every reply. Blocks until every
/// submitted request has replied. Note: if the front queue fills and no
/// shed policy drains the shards fast enough, `submit` exerts
/// backpressure and the *achieved* rate drops below the target — that
/// IS the no-shedding baseline behavior under overload (unbounded
/// waiting), which `ShedPolicy::RejectOverQuota` exists to avoid.
pub fn run_open_loop(serve: &Serve, spec: &OverloadSpec)
                     -> OverloadOutcome {
    assert!(!spec.items.is_empty(), "load mix must not be empty");
    assert!(spec.rate_rps > 0.0, "need a positive rate");
    let t0 = Instant::now();
    let interval = Duration::from_secs_f64(1.0 / spec.rate_rps);
    let (tx, rx) = channel::<Result<ServeReply, ServeError>>();
    let mut out = OverloadOutcome::default();
    // One unbounded-window session: open-loop pacing must never block
    // on a client-side window (the front queue's backpressure is the
    // experiment) — but the traffic is still session-tagged, so the
    // per-session tallies and fair admission see it.
    let session = Session::open(serve, SessionConfig {
        window: 0,
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });
    std::thread::scope(|scope| {
        let tx = tx; // moved into the submitter; clones ride each reply
        let session = &session;
        let submitter = scope.spawn(move || {
            let mut submitted = 0usize;
            for i in 0..spec.total {
                let target = t0 + interval.mul_f64(i as f64);
                let now = Instant::now();
                if now < target {
                    std::thread::sleep(target - now);
                }
                let mut item =
                    spec.items[i % spec.items.len()].clone();
                if let Some(d) = spec.deadline {
                    item = item.with_deadline_in(d);
                }
                let tx = tx.clone();
                let handle = session.submit(item)
                    .expect("open session with unbounded window");
                handle.on_ready(move |r| {
                    let _ = tx.send(r);
                });
                submitted += 1;
            }
            submitted
        });
        // Tally on this thread; the iterator ends when the submitter's
        // tx AND every reply clone have dropped = all replies in. A
        // reply callback dropped UNFIRED also drops its clone, ending
        // the loop one reply short — which fully_accounted() flags,
        // because `submitted` is counted on the submitter side.
        for reply in rx {
            match reply {
                Ok(r) => {
                    out.ok += 1;
                    *out.per_shard.entry(r.shard).or_default() += 1;
                }
                Err(ServeError::Overloaded { .. }) => out.shed += 1,
                Err(ServeError::Closed) => out.closed += 1,
                Err(e) => {
                    out.failed += 1;
                    let msg = e.to_string();
                    if !out.errors.contains(&msg) {
                        out.errors.push(msg);
                    }
                }
            }
        }
        out.submitted = submitter.join().expect("submitter panicked");
    });
    out.wall_seconds = t0.elapsed().as_secs_f64();
    out.errors.sort(); // reply arrival order is nondeterministic
    out
}

/// Render the standard load-run report: per-shard tallies (with
/// aggregate GFLOP/s where the shard executed native compute), native
/// engine and kernel splits, the unified metrics summary and the
/// accounting line. Shared by the CLI `serve` command, the bench and
/// the example. **Deterministically ordered**: every section iterates
/// a BTreeMap or a sorted list, so two runs with the same tallies
/// render byte-identical reports (diffable in CI).
pub fn outcome_report(outcome: &LoadOutcome, serve: &Serve) -> String {
    let rates: BTreeMap<String, (u64, f64)> = serve.metrics
        .compute_rates()
        .into_iter()
        .map(|(label, runs, gflops)| (label, (runs, gflops)))
        .collect();
    let mut t = Table::new(vec!["shard", "served", "GFLOP/s (agg)"])
        .numeric();
    for (shard, count) in &outcome.per_shard {
        let rate = rates.get(shard)
            .map(|(runs, g)| format!("{g:.1} over {runs} runs"))
            .unwrap_or_else(|| "-".into());
        t.row(vec![shard.clone(), count.to_string(), rate]);
    }
    let mut out = t.render();
    for (engine, count) in &outcome.per_engine {
        let _ = writeln!(out, "native engine {engine}: {count} requests");
    }
    for (kernel, count) in &outcome.per_kernel {
        let _ = writeln!(out, "native kernel {kernel}: {count} requests");
    }
    let _ = writeln!(out, "{}", serve.summary());
    let _ = writeln!(
        out,
        "{} submitted = {} ok + {} shed + {} failed in {:.3}s \
         (max batch {})",
        outcome.submitted, outcome.ok, outcome.shed, outcome.failed,
        outcome.wall_seconds, outcome.max_batch_seen);
    if !outcome.errors.is_empty() {
        let _ = writeln!(out, "errors: {:?}", outcome.errors);
    }
    out
}

/// Resolve the model-serving source for a directory: the manifest
/// under `dir` when it parses and contains a servable `model` entry,
/// otherwise the built-in demo MLP manifest written to a scratch
/// directory — with a stderr note, so the fallback is never silent
/// (same contract as [`native_config_or_synthetic`]). Returns the
/// native config to start [`Serve`] with plus the parsed spec.
pub fn model_source(dir: &Path)
                    -> crate::Result<(NativeConfig, Arc<ModelSpec>)> {
    if let Ok(m) = Manifest::load(dir) {
        if let Some(spec) = m.artifacts.iter()
            .find_map(|meta| ModelSpec::from_meta(meta).ok())
        {
            return Ok((NativeConfig::Artifacts(dir.to_path_buf()),
                       Arc::new(spec)));
        }
    }
    let scratch = std::env::temp_dir()
        .join(format!("alpaka-model-demo-{}", std::process::id()));
    std::fs::create_dir_all(&scratch)?;
    let text = crate::model::demo_manifest_text();
    std::fs::write(scratch.join("manifest.json"), &text)?;
    let m = Manifest::parse(&text, &scratch)?;
    let spec = m.artifacts.iter()
        .find_map(|meta| ModelSpec::from_meta(meta).ok())
        .ok_or_else(|| anyhow::anyhow!(
            "demo manifest lost its model entry"))?;
    eprintln!("note: no servable model manifest in {} — serving the \
               built-in demo MLP ({})", dir.display(), spec.id);
    Ok((NativeConfig::Artifacts(scratch), Arc::new(spec)))
}

/// Aggregated outcome of one model load run — the model plane's
/// accounting unit is the *plan*, not the request: a plan counts as
/// good only when **every** node settled Ok.
#[derive(Debug, Clone, Default)]
pub struct ModelLoadReport {
    /// Plans submitted (each expands to `nodes_per_plan` requests).
    pub plans: usize,
    /// Plans where every node served.
    pub plans_ok: usize,
    pub nodes_ok: usize,
    pub nodes_failed: usize,
    pub nodes_skipped: usize,
    pub wall_seconds: f64,
    /// Fully-Ok plans per wall second — the `model_serve` bench's
    /// goodput gate.
    pub goodput_pps: f64,
    /// Node id → (serves, summed native execute seconds). BTreeMap:
    /// the per-layer report renders in plan-id order, stable across
    /// runs.
    pub node_seconds: BTreeMap<String, (u64, f64)>,
    /// First root cause observed, `(node id, error)` — every skipped
    /// descendant of it reports the same cause.
    pub first_failure: Option<(String, String)>,
}

impl ModelLoadReport {
    /// Zero lost replies: every node of every plan settled exactly
    /// once (Ok, Failed or Skipped).
    pub fn fully_accounted(&self, nodes_per_plan: usize) -> bool {
        self.nodes_ok + self.nodes_failed + self.nodes_skipped
            == self.plans * nodes_per_plan
    }
}

/// Serve `total` instances of `plan` through one [`Session`] (window
/// sized to the plan, so one plan's nodes pipeline but plans queue
/// honestly). `rate_pps > 0` paces submissions open-loop at that many
/// plans per second against the submit clock (absolute schedule — a
/// slow plan doesn't push every later deadline back); `0` runs closed
/// loop. Shared by `serve --model`, the `model` subcommand and the
/// `model_serve` bench so the drivers can never drift apart.
pub fn run_model_loop(serve: &Serve, plan: &ModelPlan, total: usize,
                      rate_pps: f64) -> ModelLoadReport {
    let session = Session::open(serve, SessionConfig {
        window: plan.len().max(1),
        on_full: WindowPolicy::Block,
        close_timeout: None,
    });
    let t0 = Instant::now();
    let mut r = ModelLoadReport::default();
    for i in 0..total {
        if rate_pps > 0.0 {
            let target =
                t0 + Duration::from_secs_f64(i as f64 / rate_pps);
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
        }
        let out = session.submit_model(plan);
        r.plans += 1;
        if out.all_ok() {
            r.plans_ok += 1;
        }
        for (id, res) in &out.results {
            match res {
                NodeResult::Ok(reply) => {
                    r.nodes_ok += 1;
                    if let Output::Native { seconds, .. } =
                        &reply.output
                    {
                        let e = r.node_seconds
                            .entry(id.clone())
                            .or_insert((0, 0.0));
                        e.0 += 1;
                        e.1 += seconds;
                    }
                }
                NodeResult::Failed(e) => {
                    r.nodes_failed += 1;
                    if r.first_failure.is_none() {
                        r.first_failure =
                            Some((id.clone(), e.to_string()));
                    }
                }
                NodeResult::Skipped { .. } => r.nodes_skipped += 1,
            }
        }
    }
    session.close();
    r.wall_seconds = t0.elapsed().as_secs_f64();
    r.goodput_pps = if r.wall_seconds > 0.0 {
        r.plans_ok as f64 / r.wall_seconds
    } else {
        0.0
    };
    r
}

/// Render a model load run: per-node serve counts with mean native
/// execute time, then the plan-level accounting line. Deterministic
/// (BTreeMap iteration) like every other report here.
pub fn model_report(r: &ModelLoadReport, plan: &ModelPlan) -> String {
    let mut t = Table::new(vec!["node", "served", "mean exec ms"])
        .numeric();
    for (id, (runs, secs)) in &r.node_seconds {
        t.row(vec![id.clone(), runs.to_string(),
                   format!("{:.3}", 1e3 * secs / (*runs).max(1) as f64)]);
    }
    let mut out = format!(
        "model {} ({} tier, {} nodes/plan):\n{}",
        plan.spec.id, plan.tier.label(), plan.len(), t.render());
    let _ = writeln!(
        out,
        "{} plans = {} ok + {} degraded; nodes {} ok + {} failed + {} \
         skipped in {:.3}s ({:.1} plans/s goodput)",
        r.plans, r.plans_ok, r.plans - r.plans_ok, r.nodes_ok,
        r.nodes_failed, r.nodes_skipped, r.wall_seconds, r.goodput_pps);
    if let Some((id, cause)) = &r.first_failure {
        let _ = writeln!(out, "first failure: {id}: {cause}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{NativeConfig, ServeConfig};

    #[test]
    fn mix_covers_all_shards() {
        let items = default_mix(
            &[ArchId::Knl, ArchId::P100Nvlink],
            &["dot_n64_f32".to_string()], 1024);
        let shards: std::collections::HashSet<_> =
            items.iter().map(|i| i.shard_key()).collect();
        assert_eq!(shards.len(), 4,
                   "2 sim shards + 2 named native shards");
    }

    #[test]
    fn closed_loop_accounts_for_every_request() {
        let cfg = ServeConfig {
            cache_cap: 32,
            max_batch: 4,
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n32_f32".to_string(),
            ])),
            ..Default::default()
        };
        let serve = Serve::start(cfg).unwrap();
        let spec = LoadSpec {
            clients: 4,
            requests_per_client: 8,
            items: default_mix(&[ArchId::Knl],
                               &["dot_n32_f32".to_string()], 512),
        };
        let out = run_closed_loop(&serve, &spec);
        assert_eq!(out.submitted, 32);
        assert_eq!(out.ok + out.shed + out.failed, out.submitted);
        assert_eq!(out.failed, 0, "errors: {:?}", out.errors);
        assert_eq!(out.shed, 0, "no shed policy configured");
        assert!(out.per_shard.contains_key("sim:knl"));
        assert!(out.per_shard.contains_key("native:pjrt"));
        assert!(out.per_shard.contains_key("native:threadpool"));
        // repeats of the same small mix must hit the result cache
        assert!(serve.metrics.cache_hits() > 0);
        // every native reply names the kernel that produced it, and the
        // executed native shards surface an aggregate GFLOP/s
        assert!(out.per_kernel.keys().any(|k| k.starts_with("tuned{")),
                "{:?}", out.per_kernel);
        let rates = serve.metrics.compute_rates();
        assert!(rates.iter().any(|(label, runs, gflops)| {
            label.starts_with("native:") && *runs > 0 && *gflops > 0.0
        }), "{rates:?}");
        let report = outcome_report(&out, &serve);
        assert!(report.contains("native kernel tuned{"), "{report}");
        serve.shutdown();
    }

    #[test]
    fn stream_loop_pipelines_with_exact_accounting() {
        let cfg = ServeConfig {
            cache_cap: 32,
            max_batch: 4,
            native: Some(NativeConfig::Synthetic(vec![
                "dot_n32_f32".to_string(),
            ])),
            ..Default::default()
        };
        let serve = Serve::start(cfg).unwrap();
        let spec = LoadSpec {
            clients: 3,
            requests_per_client: 10,
            items: default_mix(&[ArchId::Knl],
                               &["dot_n32_f32".to_string()], 512),
        };
        let out = run_stream_loop(&serve, &spec, 4);
        assert_eq!(out.submitted, 30);
        assert_eq!(out.ok + out.shed + out.failed, out.submitted);
        assert_eq!(out.failed, 0, "errors: {:?}", out.errors);
        // session-tagged traffic: per-session tallies surfaced
        let tallies = serve.metrics.session_tallies();
        assert_eq!(tallies.len(), 3, "one session per client");
        for (_, t) in &tallies {
            assert_eq!(t.submitted, 10);
            assert_eq!(t.ok, 10);
        }
        assert!(serve.summary().contains("sessions"), "{}",
                serve.summary());
        serve.shutdown();
    }

    #[test]
    fn report_sections_are_deterministically_ordered() {
        // The report's inputs are nondeterministically *gathered*
        // (thread interleavings), but its rendering must be sorted:
        // per-shard / per-engine / per-kernel tallies by label,
        // errors lexicographically.
        let mut out = LoadOutcome::default();
        for shard in ["sim:knl", "native:threadpool", "native:pjrt"] {
            out.per_shard.insert(shard.into(), 1);
        }
        for kernel in ["tuned{mc=64,nc=64,kc=64,mr=4,nr=4}@store",
                       "pjrt", "tuned{mc=64,nc=64,kc=64,mr=4,nr=4}"] {
            out.per_kernel.insert(kernel.into(), 1);
        }
        out.errors = vec!["z error".into(), "a error".into()];
        out.errors.sort();
        assert_eq!(out.errors, vec!["a error".to_string(),
                                    "z error".to_string()]);
        let shards: Vec<_> = out.per_shard.keys().cloned().collect();
        assert_eq!(shards, vec!["native:pjrt", "native:threadpool",
                                "sim:knl"]);
        let kernels: Vec<_> = out.per_kernel.keys().cloned().collect();
        let mut sorted = kernels.clone();
        sorted.sort();
        assert_eq!(kernels, sorted, "per_kernel iterates sorted");
        let serve = Serve::start(ServeConfig::default()).unwrap();
        let a = outcome_report(&out, &serve);
        let b = outcome_report(&out, &serve);
        assert_eq!(a, b, "same tallies render identically");
        serve.shutdown();
    }

    #[test]
    fn chaos_config_is_replayable_and_reportable() {
        let (cfg, plan) =
            chaos_config(ServeConfig::default(), 42, 0.25, 3, 2);
        assert!(cfg.fault_plan.is_some());
        assert_eq!(cfg.retry.attempts(), 3);
        assert_eq!(cfg.quarantine.threshold, 2);
        // Same seed, same recipe: the twin plan draws the identical
        // per-site sequence — the replayability contract the chaos
        // bench gates end to end.
        let (_, twin) =
            chaos_config(ServeConfig::default(), 42, 0.25, 3, 2);
        for _ in 0..64 {
            assert_eq!(
                plan.should_fire(crate::serve::FaultSite::BackendError),
                twin.should_fire(crate::serve::FaultSite::BackendError));
        }
        assert_eq!(plan.site_counts(), twin.site_counts());
        let report = fault_report(&plan);
        assert!(report.contains("chaos seed 42"), "{report}");
        assert!(report.contains("backend-error"), "{report}");
        assert!(report.contains("tuner-commit"), "{report}");
    }

    #[test]
    fn model_loop_accounts_per_plan_and_per_node() {
        // A directory without a manifest resolves to the demo MLP
        // (never silently — stderr note), and the loop's accounting
        // holds plan-wise and node-wise.
        let dir = std::env::temp_dir()
            .join("alpaka-loadgen-model-test-absent");
        let (native, spec) = model_source(&dir).unwrap();
        let serve = Serve::start(ServeConfig {
            native: Some(native),
            ..Default::default()
        }).unwrap();
        let plan =
            ModelPlan::compile(&spec, crate::model::Tier::Fused);
        let out = run_model_loop(&serve, &plan, 3, 0.0);
        assert_eq!(out.plans, 3);
        assert_eq!(out.plans_ok, 3, "{:?}", out.first_failure);
        assert!(out.fully_accounted(plan.len()));
        assert_eq!(out.nodes_ok, 3 * plan.len());
        assert_eq!(out.node_seconds.len(), plan.len(),
                   "every layer node served natively");
        let report = model_report(&out, &plan);
        assert!(report.contains("3 plans = 3 ok + 0 degraded"),
                "{report}");
        assert!(report.contains("#L0"), "{report}");
        serve.shutdown();
    }

    #[test]
    fn open_loop_accounts_for_every_request_under_forced_shed() {
        // quota 0 on a rejecting policy: every routed request is shed —
        // a fully deterministic overload outcome.
        let serve = Serve::start(ServeConfig {
            shed: crate::serve::ShedPolicy::RejectOverQuota,
            shard_quota: Some(0),
            ..Default::default()
        }).unwrap();
        let spec = OverloadSpec {
            rate_rps: 10_000.0,
            total: 40,
            items: default_mix(&[ArchId::Knl], &[], 512),
            deadline: None,
        };
        let out = run_open_loop(&serve, &spec);
        assert_eq!(out.submitted, 40);
        assert!(out.fully_accounted());
        assert_eq!(out.shed, 40, "quota 0 sheds everything: {out:?}");
        assert_eq!(serve.metrics.shed(), 40);
        serve.shutdown();
    }
}
