//! Persistent result cache — the serve-layer LRU spilled to disk.
//!
//! A [`DiskResultCache`] is a JSON file of completed **native** outputs
//! keyed by the work item's canonical cache key, each entry guarded by
//! the artifact's identity **digest** (id, shape, dtype, input seeds,
//! coefficients — see `backend::spec_digest`): a manifest change under
//! the same artifact id reads as a miss, never a stale replay. Sim
//! predictions are not spilled (the model is deterministic and cheap —
//! the disk exists to save *native compute* across restarts) and the
//! tuner shard has its own store.
//!
//! Reuses the tuning store's robustness machinery
//! ([`TuningStore::write_atomic`]) and mirrors its contract:
//!
//! * **Atomic writes** — temp file + rename;
//! * **Corrupt-file recovery** — unparseable bytes open as an empty
//!   cache (stderr note), never a panic;
//! * **Schema versioning** — a mismatched `schema` detaches persistence
//!   (the file is served-around and never overwritten);
//! * **Unreadable file** — detaches persistence so a later save cannot
//!   clobber unread state.
//!
//! Wiring: `ServeConfig::result_cache_path` enables it; shard workers
//! probe it after a memory-LRU miss (hits seed the LRU and are labelled
//! `cache:disk` in replies/metrics, vs `cache:mem`) and write through
//! on every executed native result.
//!
//! **Bounded**: [`DiskResultCache::with_cap`] caps the entry count
//! (`ServeConfig::result_cache_cap`, CLI `--result-cache-cap`);
//! inserts evict oldest-first by a persisted per-entry insertion
//! sequence, so the spill file cannot grow without bound and the
//! eviction order survives restarts. Evictions are returned to the
//! caller and counted in `ServeMetrics`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::autotune::store::escape;
use crate::autotune::TuningStore;
use crate::util::json;

use super::backend::{NativeEngine, Output};

/// On-disk format version; bump on incompatible change.
pub const RESULT_CACHE_SCHEMA: u64 = 1;

/// One spilled native result.
#[derive(Debug, Clone, PartialEq)]
pub struct DiskEntry {
    /// Work-item cache key (e.g. `artifact:dot_n64_f32`).
    pub key: String,
    /// Identity digest of the artifact spec at write time.
    pub digest: String,
    pub artifact_id: String,
    pub seconds: f64,
    pub gflops: Option<f64>,
    /// [`NativeEngine::slug`] of the engine that produced it.
    pub engine: String,
    pub kernel: String,
    /// Insertion sequence — monotonic per cache lifetime, persisted so
    /// oldest-first eviction survives restarts. Additive to schema 1:
    /// entries written before the bound existed read back as 0
    /// (evicted first, which is exactly right — they are the oldest).
    pub seq: u64,
}

/// The JSON-on-disk result cache. See the module docs for the
/// robustness contract.
#[derive(Debug)]
pub struct DiskResultCache {
    path: Option<PathBuf>,
    entries: BTreeMap<String, DiskEntry>,
    /// Maximum entries kept; 0 = unbounded.
    max_entries: usize,
    /// Next insertion sequence number.
    next_seq: u64,
}

impl DiskResultCache {
    /// Open (or create) a cache at `path`. Never fails — see module
    /// docs for the recovery/detach rules.
    pub fn open(path: &Path) -> Self {
        let mut cache = Self {
            path: Some(path.to_path_buf()),
            entries: BTreeMap::new(),
            max_entries: 0,
            next_seq: 0,
        };
        match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                eprintln!("[serve] result cache {}: read failed ({e}); \
                           running detached (in-memory) so the unread \
                           file is never overwritten", path.display());
                cache.path = None;
            }
            Ok(text) => match parse_entries(&text) {
                Ok(entries) => {
                    cache.next_seq = entries.values()
                        .map(|e| e.seq + 1).max().unwrap_or(0);
                    cache.entries = entries;
                }
                Err(Refusal::Corrupt(msg)) => {
                    eprintln!("[serve] result cache {}: {msg}; \
                               starting empty", path.display());
                }
                Err(Refusal::Schema(msg)) => {
                    eprintln!("[serve] result cache {}: {msg}; running \
                               detached (in-memory) so the \
                               incompatible file is never overwritten",
                              path.display());
                    cache.path = None;
                }
            },
        }
        cache
    }

    /// A cache with no backing file (tests).
    pub fn in_memory() -> Self {
        Self {
            path: None,
            entries: BTreeMap::new(),
            max_entries: 0,
            next_seq: 0,
        }
    }

    /// Bound the cache to `max_entries` (0 = unbounded), evicting
    /// oldest-first immediately if already over.
    pub fn with_cap(mut self, max_entries: usize) -> Self {
        self.max_entries = max_entries;
        self.evict_to_cap();
        self
    }

    pub fn cap(&self) -> usize {
        self.max_entries
    }

    /// Evict oldest entries (minimum `seq`) until within the cap;
    /// returns how many were dropped.
    fn evict_to_cap(&mut self) -> u64 {
        if self.max_entries == 0 {
            return 0;
        }
        let mut evicted = 0;
        while self.entries.len() > self.max_entries {
            let Some(oldest) = self.entries.values()
                .min_by_key(|e| e.seq)
                .map(|e| e.key.clone())
            else {
                break;
            };
            self.entries.remove(&oldest);
            evicted += 1;
        }
        evicted
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up `key`, requiring the stored identity digest to match —
    /// an entry written for a different artifact identity (changed
    /// manifest, different seeds) is a miss, and unparseable stored
    /// engines are misses rather than fabricated outputs.
    pub fn get(&self, key: &str, digest: &str) -> Option<Output> {
        let e = self.entries.get(key)?;
        if e.digest != digest {
            return None;
        }
        let engine = NativeEngine::parse(&e.engine)?;
        Some(Output::Native {
            artifact_id: e.artifact_id.clone(),
            seconds: e.seconds,
            gflops: e.gflops,
            engine,
            kernel: e.kernel.clone(),
        })
    }

    /// Record an executed output under `(key, digest)`. Only native
    /// outputs spill; `None` means nothing was stored,
    /// `Some(evicted)` how many old entries the bound pushed out
    /// (re-inserting a key refreshes its recency). The caller persists
    /// via [`DiskResultCache::snapshot`] +
    /// [`TuningStore::write_atomic`] *outside* its lock.
    pub fn put(&mut self, key: &str, digest: &str, output: &Output)
               -> Option<u64> {
        let Output::Native { artifact_id, seconds, gflops, engine,
                             kernel } = output
        else {
            return None;
        };
        let seq = self.next_seq;
        self.next_seq += 1;
        self.entries.insert(key.to_string(), DiskEntry {
            key: key.to_string(),
            digest: digest.to_string(),
            artifact_id: artifact_id.clone(),
            seconds: *seconds,
            gflops: *gflops,
            engine: engine.slug().to_string(),
            kernel: kernel.clone(),
            seq,
        });
        Some(self.evict_to_cap())
    }

    /// Persistence target plus serialized bytes (`None` when detached).
    pub fn snapshot(&self) -> Option<(PathBuf, String)> {
        self.path.clone().map(|p| (p, self.serialize()))
    }

    /// The on-disk JSON form (deterministic: entries in key order).
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out,
                         "{{\n  \"schema\": {RESULT_CACHE_SCHEMA},");
        let _ = writeln!(out, "  \"entries\": [");
        let total = self.entries.len();
        for (i, e) in self.entries.values().enumerate() {
            let comma = if i + 1 == total { "" } else { "," };
            let gflops = e.gflops
                .map(|g| format!("{g:.6}"))
                .unwrap_or_else(|| "null".into());
            let _ = writeln!(
                out,
                "    {{\"key\": \"{}\", \"digest\": \"{}\", \
                 \"artifact_id\": \"{}\", \"seconds\": {:.9}, \
                 \"gflops\": {gflops}, \"engine\": \"{}\", \
                 \"kernel\": \"{}\", \"seq\": {}}}{comma}",
                escape(&e.key), escape(&e.digest),
                escape(&e.artifact_id), e.seconds, escape(&e.engine),
                escape(&e.kernel), e.seq);
        }
        out.push_str("  ]\n}\n");
        out
    }
}

#[derive(Debug)]
enum Refusal {
    Corrupt(String),
    Schema(String),
}

fn parse_entries(text: &str)
                 -> Result<BTreeMap<String, DiskEntry>, Refusal> {
    let doc = json::parse(text)
        .map_err(|e| Refusal::Corrupt(format!("corrupt: {e}")))?;
    let schema = doc.get("schema").and_then(|v| v.as_u64())
        .ok_or_else(|| Refusal::Corrupt(
            "corrupt: no schema field".to_string()))?;
    if schema != RESULT_CACHE_SCHEMA {
        return Err(Refusal::Schema(format!(
            "schema {schema} != supported {RESULT_CACHE_SCHEMA}: \
             refusing stale data")));
    }
    let list = doc.get("entries").and_then(|v| v.as_array())
        .ok_or_else(|| Refusal::Corrupt(
            "corrupt: no entries array".to_string()))?;
    let mut entries = BTreeMap::new();
    for (i, item) in list.iter().enumerate() {
        match parse_entry(item) {
            Some(e) => {
                entries.insert(e.key.clone(), e);
            }
            None => {
                eprintln!("[serve] result cache: skipping malformed \
                           entry #{i}");
            }
        }
    }
    Ok(entries)
}

fn parse_entry(v: &json::Value) -> Option<DiskEntry> {
    let seconds = v.get("seconds")?.as_f64()?;
    if !(seconds > 0.0) || !seconds.is_finite() {
        return None;
    }
    Some(DiskEntry {
        key: v.get("key")?.as_str()?.to_string(),
        digest: v.get("digest")?.as_str()?.to_string(),
        artifact_id: v.get("artifact_id")?.as_str()?.to_string(),
        seconds,
        // absent or null gflops both read back as None
        gflops: v.get("gflops").and_then(|g| g.as_f64()),
        engine: v.get("engine")?.as_str()?.to_string(),
        kernel: v.get("kernel")?.as_str()?.to_string(),
        // additive in schema 1: pre-bound files have no seq — read as
        // 0 so legacy entries evict first
        seq: v.get("seq").and_then(|n| n.as_u64()).unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native(id: &str) -> Output {
        Output::Native {
            artifact_id: id.to_string(),
            seconds: 0.0125,
            gflops: Some(3.5),
            engine: NativeEngine::ThreadpoolGemm,
            kernel: "tuned{mc=64,nc=64,kc=64,mr=4,nr=4}".to_string(),
        }
    }

    #[test]
    fn roundtrip_through_serialize() {
        let mut c = DiskResultCache::in_memory();
        assert!(c.is_empty());
        assert_eq!(c.put("artifact:x", "digest-1", &native("x")),
                   Some(0));
        let reparsed = parse_entries(&c.serialize()).unwrap();
        assert_eq!(reparsed.len(), 1);
        let e = reparsed.get("artifact:x").unwrap();
        assert_eq!(e.digest, "digest-1");
        assert_eq!(e.engine, "threadpool-gemm");
        assert!((e.seconds - 0.0125).abs() < 1e-12);
        assert!((e.gflops.unwrap() - 3.5).abs() < 1e-6);
    }

    #[test]
    fn digest_mismatch_is_a_miss() {
        let mut c = DiskResultCache::in_memory();
        c.put("artifact:x", "digest-1", &native("x"));
        assert!(c.get("artifact:x", "digest-1").is_some());
        assert!(c.get("artifact:x", "digest-2").is_none(),
                "changed identity must never replay a stale result");
        assert!(c.get("artifact:y", "digest-1").is_none());
    }

    #[test]
    fn only_native_outputs_spill() {
        use crate::gemm::Precision;
        let mut c = DiskResultCache::in_memory();
        let tuned = Output::Tuned {
            dtype: Precision::F64,
            bucket: 64,
            params: "mc=64".into(),
            gflops: 1.0,
            evals: 1,
            seconds: 0.1,
            committed: true,
        };
        assert!(c.put("explore:f64:64", "d", &tuned).is_none());
        assert!(c.is_empty());
    }

    #[test]
    fn corrupt_text_recovers_to_empty_schema_detaches() {
        for bad in ["", "{", "not json", r#"{"entries": []}"#] {
            assert!(matches!(parse_entries(bad),
                             Err(Refusal::Corrupt(_))), "{bad:?}");
        }
        match parse_entries(r#"{"schema": 99, "entries": []}"#) {
            Err(Refusal::Schema(m)) => {
                assert!(m.contains("refusing stale data"), "{m}");
            }
            other => panic!("misclassified: {other:?}"),
        }
    }

    #[test]
    fn null_gflops_roundtrips_as_none() {
        let mut c = DiskResultCache::in_memory();
        c.put("artifact:z", "d", &Output::Native {
            artifact_id: "z".into(),
            seconds: 0.5,
            gflops: None,
            engine: NativeEngine::Pjrt,
            kernel: "pjrt".into(),
        });
        let entries = parse_entries(&c.serialize()).unwrap();
        assert_eq!(entries.get("artifact:z").unwrap().gflops, None);
    }

    #[test]
    fn on_disk_roundtrip_is_atomic_and_recovers() {
        let dir = std::env::temp_dir().join("alpaka-diskcache-test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("result_cache.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut c = DiskResultCache::open(&path);
            assert!(c.is_empty());
            c.put("artifact:x", "d1", &native("x"));
            let (p, json) = c.snapshot().expect("persistent");
            TuningStore::write_atomic(&p, &json).unwrap();
        }
        {
            let c = DiskResultCache::open(&path);
            assert_eq!(c.len(), 1);
            assert!(c.get("artifact:x", "d1").is_some());
        }
        // corrupt file: recovered to empty, path kept for next save
        std::fs::write(&path, "garbage{{{").unwrap();
        let c = DiskResultCache::open(&path);
        assert!(c.is_empty());
        assert!(c.path().is_some());
        // schema mismatch: detached
        std::fs::write(&path,
                       r#"{"schema": 999, "entries": []}"#).unwrap();
        let c = DiskResultCache::open(&path);
        assert!(c.is_empty());
        assert!(c.path().is_none(), "incompatible file never clobbered");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn cap_evicts_oldest_first_on_insert() {
        let mut c = DiskResultCache::in_memory().with_cap(2);
        assert_eq!(c.cap(), 2);
        assert_eq!(c.put("k1", "d", &native("a")), Some(0));
        assert_eq!(c.put("k2", "d", &native("b")), Some(0));
        // third insert pushes out k1 (the oldest)
        assert_eq!(c.put("k3", "d", &native("c")), Some(1));
        assert_eq!(c.len(), 2);
        assert!(c.get("k1", "d").is_none(), "oldest entry evicted");
        assert!(c.get("k2", "d").is_some());
        assert!(c.get("k3", "d").is_some());
        // re-inserting k2 refreshes its recency: k3 is now oldest
        assert_eq!(c.put("k2", "d", &native("b2")), Some(0));
        assert_eq!(c.put("k4", "d", &native("d4")), Some(1));
        assert!(c.get("k3", "d").is_none());
        assert!(c.get("k2", "d").is_some());
    }

    #[test]
    fn zero_cap_means_unbounded() {
        let mut c = DiskResultCache::in_memory();
        for i in 0..100 {
            assert_eq!(c.put(&format!("k{i}"), "d", &native("x")),
                       Some(0));
        }
        assert_eq!(c.len(), 100);
    }

    #[test]
    fn seq_roundtrips_and_eviction_order_survives_reload() {
        let dir = std::env::temp_dir().join("alpaka-diskcache-seq");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("result_cache.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut c = DiskResultCache::open(&path);
            c.put("old", "d", &native("a"));
            c.put("new", "d", &native("b"));
            let (p, json) = c.snapshot().expect("persistent");
            TuningStore::write_atomic(&p, &json).unwrap();
        }
        // reopen bounded: the persisted seq keeps "old" first in line
        let mut c = DiskResultCache::open(&path).with_cap(2);
        assert_eq!(c.len(), 2);
        assert_eq!(c.put("k3", "d", &native("c")), Some(1));
        assert!(c.get("old", "d").is_none(),
                "persisted insertion order drives eviction");
        assert!(c.get("new", "d").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn legacy_entries_without_seq_read_as_zero() {
        let text = r#"{"schema": 1, "entries": [
            {"key": "k", "digest": "d", "artifact_id": "a",
             "seconds": 0.5, "gflops": null, "engine": "pjrt",
             "kernel": "pjrt"}
        ]}"#;
        let entries = parse_entries(text).unwrap();
        assert_eq!(entries.get("k").unwrap().seq, 0);
    }
}
