//! `alpaka-bench` — the leader binary: tuning campaigns on the simulated
//! testbed, native PJRT runs of the real Pallas kernel, and regeneration
//! of every paper table/figure.

use std::path::Path;

use alpaka_rs::arch::{compiler, ArchId, CompilerId};
use alpaka_rs::cli::{Cli, CommandSpec, OptSpec, Parsed};
use alpaka_rs::coordinator::Scheduler;
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::report;
use alpaka_rs::runtime::{executor, Manifest, Runtime};
use alpaka_rs::sim::{Machine, MemMode, TuningPoint};
use alpaka_rs::tuner::{self, Strategy, TuningSpace};
use alpaka_rs::util::table::Table;
use alpaka_rs::Result;

fn cli() -> Cli {
    Cli {
        binary: "alpaka-bench",
        about: "single-source kernel tuning across many-core \
                architectures (Matthes et al. 2017 reproduction)",
        commands: vec![
            CommandSpec {
                name: "archs",
                about: "list architectures, compilers and peaks",
                opts: vec![],
            },
            CommandSpec {
                name: "predict",
                about: "predict GFLOP/s for one tuning point",
                opts: vec![
                    OptSpec::value("arch", Some("knl"), "architecture"),
                    OptSpec::value("compiler", None,
                                   "compiler (default: vendor)"),
                    OptSpec::value("precision", Some("f64"), "f32|f64"),
                    OptSpec::value("n", Some("10240"), "matrix size"),
                    OptSpec::value("t", Some("64"), "tile size"),
                    OptSpec::value("threads", Some("1"),
                                   "hw threads per core"),
                    OptSpec::value("memmode", Some("default"),
                                   "default|flat|ddr|unified"),
                ],
            },
            CommandSpec {
                name: "tune",
                about: "run the paper's multidimensional tuning",
                opts: vec![
                    OptSpec::value("arch", Some("knl"), "architecture"),
                    OptSpec::value("compiler", None,
                                   "compiler (default: vendor)"),
                    OptSpec::value("precision", Some("f64"), "f32|f64"),
                    OptSpec::value("n", Some("10240"), "matrix size"),
                    OptSpec::value("strategy", Some("grid"),
                                   "grid|random|hillclimb|anneal"),
                    OptSpec::value("budget", Some("24"),
                                   "evaluations for auto-tuners"),
                    OptSpec::value("workers", Some("0"),
                                   "scheduler workers (0 = cores)"),
                ],
            },
            CommandSpec {
                name: "autotune",
                about: "autotune the packed host GEMM kernel by \
                        MEASURED GFLOP/s (the paper's Fig. 3 sweep on \
                        this machine)",
                opts: vec![
                    OptSpec::flag("measured",
                                  "time the real kernel per point \
                                   (required; model-based sweeps live \
                                   under `tune`)"),
                    OptSpec::value("n", Some("512"), "matrix size"),
                    OptSpec::value("precision", Some("f64"), "f32|f64"),
                    OptSpec::value("reps", Some("5"),
                                   "timed runs per point (best-of)"),
                    OptSpec::value("store", None,
                                   "tuning-store path: commit the \
                                    winner for serving (same store \
                                    `serve --tuning-store` reads)"),
                    OptSpec::flag("warm",
                                  "with --store: pre-populate the \
                                   other serving buckets (64..512) \
                                   with quick budgeted explorations"),
                ],
            },
            CommandSpec {
                name: "repro",
                about: "regenerate paper tables/figures into --out-dir",
                opts: vec![
                    OptSpec::flag("all", "write everything"),
                    OptSpec::value("out-dir", Some("reports"),
                                   "output directory"),
                ],
            },
            CommandSpec {
                name: "native",
                about: "run the real Pallas-kernel artifacts via PJRT",
                opts: vec![
                    OptSpec::value("artifacts-dir", Some("artifacts"),
                                   "artifact directory"),
                    OptSpec::value("role", None,
                                   "filter by role (e.g. tile_sweep)"),
                    OptSpec::value("id", None, "run one artifact id"),
                    OptSpec::value("runs", Some("10"),
                                   "timed runs (paper: 10)"),
                    OptSpec::flag("verify",
                                  "digest-verify instead of timing"),
                ],
            },
            CommandSpec {
                name: "inspect-hlo",
                about: "show that the abstraction compiles away \
                        (Listing 1.2 analogue)",
                opts: vec![
                    OptSpec::value("artifacts-dir", Some("artifacts"),
                                   "artifact directory"),
                    OptSpec::value("id", Some("gemm_n128_t16_e1_f32"),
                                   "artifact id"),
                ],
            },
            CommandSpec {
                name: "serve",
                about: "closed-loop load test of the unified serve \
                        layer (sim shards + native shard)",
                opts: vec![
                    OptSpec::value("clients", Some("8"),
                                   "concurrent closed-loop clients"),
                    OptSpec::value("sessions", Some("0"),
                                   "client-plane sessions (0 = use \
                                    --clients; each session is one \
                                    client thread)"),
                    OptSpec::value("window", Some("1"),
                                   "per-session in-flight window \
                                    (1 = classic closed loop; >1 \
                                    pipelines via submit_stream)"),
                    OptSpec::value("requests", Some("64"),
                                   "requests per client"),
                    OptSpec::value("archs", Some("knl,p100-nvlink"),
                                   "comma-separated simulated archs"),
                    OptSpec::value("artifacts-dir", Some("artifacts"),
                                   "native-shard artifact directory \
                                    (falls back to a synthetic catalog)"),
                    OptSpec::value("n", Some("1024"),
                                   "matrix size for simulated points"),
                    OptSpec::value("max-batch", Some("8"),
                                   "max coalesced batch per shard"),
                    OptSpec::value("cache", Some("128"),
                                   "LRU result-cache entries per shard \
                                    (0 disables)"),
                    OptSpec::value("queue", Some("64"),
                                   "front/shard queue capacity"),
                    OptSpec::value("sim-threads", Some("2"),
                                   "worker threads per sim shard"),
                    OptSpec::value("native-threads", Some("4"),
                                   "threads in the native:threadpool \
                                    backend's pool (0 = host-sized)"),
                    OptSpec::value("shed", Some("none"),
                                   "shed policy: none|reject|expire"),
                    OptSpec::value("quota", Some("0"),
                                   "per-shard admission quota \
                                    (0 = unlimited)"),
                    OptSpec::value("deadline-ms", Some("0"),
                                   "per-request deadline in ms \
                                    (0 = none; pair with --shed expire)"),
                    OptSpec::flag("overload",
                                  "drive an open-loop overload scenario \
                                   (~4x the measured sustainable rate) \
                                   instead of the closed loop"),
                    OptSpec::value("rate", Some("0"),
                                   "open-loop rate in req/s for \
                                    --overload (0 = auto: 4x measured)"),
                    OptSpec::value("tuning-store", None,
                                   "persistent tuning store: native \
                                    shards serve each request with its \
                                    bucket's measured-best params"),
                    OptSpec::value("result-cache", None,
                                   "persistent result cache: executed \
                                    native results spill to this JSON \
                                    file (hits labelled cache:disk); \
                                    needs --cache > 0"),
                    OptSpec::value("result-cache-cap", Some("1024"),
                                   "max entries the persistent result \
                                    cache keeps (oldest evicted first; \
                                    0 = unbounded)"),
                    OptSpec::flag("online-tune",
                                  "background-tune untuned buckets \
                                   while serving (commits to \
                                   --tuning-store, or an in-memory \
                                   store)"),
                    OptSpec::value("chaos-seed", Some("0"),
                                   "deterministic fault injection \
                                    seeded here (0 = off): backend \
                                    errors at --fault-rate, corruption \
                                    and worker panics at half of it; \
                                    same seed replays the same chaos"),
                    OptSpec::value("fault-rate", Some("0.1"),
                                   "per-attempt injected fault \
                                    probability for --chaos-seed"),
                    OptSpec::value("retries", Some("1"),
                                   "total execution attempts per \
                                    request (1 = no retry; applies to \
                                    Backend/Corrupted failures, never \
                                    Overloaded/Closed)"),
                    OptSpec::value("quarantine-after", Some("0"),
                                   "consecutive post-retry failures \
                                    before an artifact is quarantined \
                                    (fail-fast circuit breaker; \
                                    0 = off)"),
                    OptSpec::value("trace", None,
                                   "turn the per-request flight \
                                    recorder on and write its \
                                    Chrome-trace JSON here on exit \
                                    (load in chrome://tracing or \
                                    render with `trace`)"),
                    OptSpec::value("trace-cap", Some("256"),
                                   "flight-recorder ring capacity \
                                    for --trace"),
                    OptSpec::value("model", None,
                                   "serve a compiled model plan \
                                    instead of the mixed load: load \
                                    the MLP manifest entry under this \
                                    directory (built-in demo MLP when \
                                    absent) and drive --requests \
                                    fused-tier plans through one \
                                    session"),
                    OptSpec::value("model-rate", Some("0"),
                                   "open-loop pacing for --model, \
                                    plans per second (0 = closed \
                                    loop)"),
                ],
            },
            CommandSpec {
                name: "model",
                about: "compile an MLP manifest entry into per-tier \
                        plans (fused / unfused / strict) and serve \
                        each end-to-end, printing per-layer timings",
                opts: vec![
                    OptSpec::value("dir", None,
                                   "artifact directory holding the \
                                    model manifest (or pass it \
                                    positionally; built-in demo MLP \
                                    when absent)"),
                    OptSpec::value("repeat", Some("3"),
                                   "plans served per tier (per-layer \
                                    times average over these)"),
                    OptSpec::value("native-threads", Some("4"),
                                   "threadpool shard worker count"),
                ],
            },
            CommandSpec {
                name: "trace",
                about: "render a Chrome-trace export (from `serve \
                        --trace`) as a text waterfall, slowest first",
                opts: vec![
                    OptSpec::value("input", None,
                                   "trace JSON path (or pass it \
                                    positionally)"),
                    OptSpec::value("top", Some("5"),
                                   "how many slowest traces to render"),
                ],
            },
            CommandSpec {
                name: "lint",
                about: "pallas-lint: machine-check the crate's \
                        concurrency/accounting invariants (R1-R9) \
                        over its own sources",
                opts: vec![
                    OptSpec::flag("deny",
                                  "exit non-zero when any diagnostic \
                                   survives (CI gate)"),
                    OptSpec::value("json", None,
                                   "write the machine-readable report \
                                    to this path"),
                    OptSpec::value("graph", None,
                                   "dump the interprocedural call \
                                    graph (GraphViz DOT) to this \
                                    path"),
                    OptSpec::value("root", None,
                                   "tree to lint: directory holding \
                                    rust/src and examples (default: \
                                    this crate's manifest dir)"),
                ],
            },
            CommandSpec {
                name: "mappings",
                about: "print the Fig. 5 hierarchy mappings",
                opts: vec![],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let parsed = match cli.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&cli, &parsed) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn parse_combo(p: &Parsed) -> Result<(ArchId, CompilerId, Precision)> {
    let arch = ArchId::parse(p.get_or("arch", "knl"))
        .ok_or_else(|| anyhow::anyhow!("unknown arch"))?;
    let comp = match p.get("compiler") {
        Some(c) => CompilerId::parse(c)
            .ok_or_else(|| anyhow::anyhow!("unknown compiler"))?,
        None => compiler::vendor_compiler(arch),
    };
    let prec = Precision::parse(p.get_or("precision", "f64"))
        .ok_or_else(|| anyhow::anyhow!("unknown precision"))?;
    Ok((arch, comp, prec))
}

fn run(cli: &Cli, p: &Parsed) -> Result<()> {
    match p.command.as_str() {
        "help" => {
            println!("{}", cli.help());
            Ok(())
        }
        "archs" => cmd_archs(),
        "predict" => cmd_predict(p),
        "tune" => cmd_tune(p),
        "autotune" => cmd_autotune(p),
        "repro" => cmd_repro(p),
        "native" => cmd_native(p),
        "serve" => cmd_serve(p),
        "model" => cmd_model(p),
        "trace" => cmd_trace(p),
        "lint" => cmd_lint(p),
        "inspect-hlo" => cmd_inspect(p),
        "mappings" => {
            println!("{}", report::figures::fig5_mappings());
            Ok(())
        }
        other => anyhow::bail!("unhandled command {other}"),
    }
}

fn cmd_archs() -> Result<()> {
    let mut t = Table::new(vec!["arch", "class", "compilers",
                                "peak SP GF/s", "peak DP GF/s"])
        .numeric();
    for arch in ArchId::PAPER.iter().chain([ArchId::Host].iter()) {
        let spec = arch.spec();
        let comps = compiler::valid_compilers(*arch)
            .iter().map(|c| c.label()).collect::<Vec<_>>().join("/");
        t.row(vec![
            arch.label().to_string(),
            format!("{:?}", spec.class),
            comps,
            format!("{:.0}", spec.peak_gflops(Precision::F32)),
            format!("{:.0}", spec.peak_gflops(Precision::F64)),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_predict(p: &Parsed) -> Result<()> {
    let (arch, comp, prec) = parse_combo(p)?;
    let n = p.get_u64("n")?.unwrap_or(GemmWorkload::TUNING_N);
    let t = p.get_u64("t")?.unwrap_or(64);
    let h = p.get_u64("threads")?.unwrap_or(1);
    let mode = MemMode::parse(p.get_or("memmode", "default"))
        .ok_or_else(|| anyhow::anyhow!("unknown memmode"))?;
    let machine = Machine::for_arch(arch);
    let point = TuningPoint { arch, compiler: comp, precision: prec, n,
                              t, hw_threads: h, memmode: mode,
                              thread_override: None };
    let pred = machine.predict(&point);
    println!("{} {} {} N={n} T={t} h={h} [{}]:", arch.label(),
             comp.label(), prec.dtype(), mode.label());
    println!("  {:.1} GFLOP/s ({:.1}% of peak), {:?}-bound, {:.4}s",
             pred.gflops, 100.0 * pred.relative_peak, pred.bound,
             pred.seconds);
    Ok(())
}

fn cmd_tune(p: &Parsed) -> Result<()> {
    let (arch, comp, prec) = parse_combo(p)?;
    let n = p.get_u64("n")?.unwrap_or(GemmWorkload::TUNING_N);
    let strategy = Strategy::parse(p.get_or("strategy", "grid"))
        .ok_or_else(|| anyhow::anyhow!("unknown strategy"))?;
    let budget = p.get_u64("budget")?.unwrap_or(24) as usize;
    let space = TuningSpace::paper(arch, comp, prec, n);
    println!("tuning {} {} {} over {} points (strategy: {})",
             arch.label(), comp.label(), prec.dtype(), space.len(),
             strategy.label());

    if strategy == Strategy::Grid {
        // the paper's exhaustive sweep, through the coordinator
        let workers = p.get_u64("workers")?.unwrap_or(0) as usize;
        let workers = if workers == 0 {
            std::thread::available_parallelism().map(|n| n.get())
                .unwrap_or(4)
        } else {
            workers
        };
        let sched = Scheduler::new(workers, 64);
        let results = sched.run_batch(space.points());
        let mut sweep = tuner::SweepResults::default();
        for r in results {
            sweep.push(r.record);
        }
        let best = sweep.best()
            .ok_or_else(|| anyhow::anyhow!("empty sweep"))?;
        println!("  best: T={} h={} -> {:.1} GFLOP/s ({:.1}% of peak)",
                 best.point.t, best.point.hw_threads, best.gflops,
                 100.0 * best.relative_peak);
        for r in sweep.top_k(5) {
            println!("    T={:<4} h={} {:>9.1} GF/s  {:?}", r.point.t,
                     r.point.hw_threads, r.gflops, r.bound);
        }
        println!("  {}", sched.metrics.summary());
    } else {
        let machine = Machine::for_arch(arch);
        let out = tuner::tune_with(strategy, &machine, &space, budget,
                                   0xA1FA);
        println!("  best: T={} h={} -> {:.1} GFLOP/s after {} evals",
                 out.best.point.t, out.best.point.hw_threads,
                 out.best.gflops, out.evals);
    }
    Ok(())
}

fn cmd_autotune(p: &Parsed) -> Result<()> {
    use alpaka_rs::tuner::measured;
    use alpaka_rs::util::threadpool::ThreadPool;

    anyhow::ensure!(
        p.has_flag("measured"),
        "autotune times the real kernel: pass --measured (model-based \
         sweeps live under `tune`)");
    let n = p.get_u64("n")?.unwrap_or(512);
    anyhow::ensure!(n >= 1, "need n >= 1");
    let prec = Precision::parse(p.get_or("precision", "f64"))
        .ok_or_else(|| anyhow::anyhow!("unknown precision"))?;
    let reps = p.get_u64("reps")?.unwrap_or(5).max(1) as usize;
    let space = TuningSpace::paper(ArchId::Host,
                                   compiler::vendor_compiler(ArchId::Host),
                                   prec, n);
    anyhow::ensure!(
        !space.t_values.is_empty(),
        "no legal tile sizes for N={n} (pick an N divisible by a power \
         of two >= 16)");
    println!("measured autotune: host kernel, {} {}, N={n}, {} points, \
              best-of-{reps} per point",
             ArchId::Host.label(), prec.dtype(), space.len());
    // Single-worker pool: points are timed sequentially, so wall-time
    // measurements never contend with each other.
    let pool = ThreadPool::new(1);
    let (results, failures) = measured::try_measured_sweep(&space, reps,
                                                           &pool);
    anyhow::ensure!(failures.is_empty(),
                    "measured evaluations panicked: {failures:?}");
    let mut t = Table::new(vec!["T", "kernel params", "GFLOP/s",
                                "% host peak"]).numeric();
    for r in &results.records {
        t.row(vec![
            r.point.t.to_string(),
            measured::params_for_point(&r.point).label(),
            format!("{:.2}", r.gflops),
            format!("{:.1}", 100.0 * r.relative_peak),
        ]);
    }
    println!("{}", t.render());
    let best = results.best()
        .ok_or_else(|| anyhow::anyhow!("empty sweep"))?;
    let params = measured::params_for_point(&best.point);
    println!("best: T={} -> {:.2} GFLOP/s  (KernelParams {{{}}}, \
              self-consistency {:.3})",
             best.point.t, best.gflops, params.label(),
             measured::self_consistency(&results).unwrap_or(0.0));

    // Persist the winner for the serve layer: the SAME store
    // `serve --tuning-store` reads (and --online-tune feeds).
    if let Some(store_path) = p.get("store") {
        use alpaka_rs::autotune::{self, TuningStore};

        let mut store = TuningStore::open(Path::new(store_path));
        let bucket = autotune::bucket_for(n);
        if bucket == n {
            store.commit(prec, bucket, params, best.gflops,
                         reps as u64)?;
            println!("committed {} n<={bucket} -> {{{}}} into {}",
                     prec.dtype(), params.label(), store_path);
        } else {
            eprintln!("note: N={n} is not a bucket size (bucket \
                       {bucket}); not committing a sweep measured off \
                       its bucket — rerun with a power-of-two N or use \
                       --warm");
        }
        if p.has_flag("warm") {
            for bucket in [64u64, 128, 256, 512] {
                if store.lookup(prec, bucket).is_some() {
                    continue;
                }
                let out = autotune::explore_bucket(prec, bucket, 4,
                                                   reps.min(3));
                store.commit(prec, bucket, out.params, out.gflops,
                             reps.min(3) as u64)?;
                println!("warmed {} n<={bucket} -> {{{}}} \
                          ({:.2} GF/s, {} evals)",
                         prec.dtype(), out.params.label(), out.gflops,
                         out.evals);
            }
        }
        print!("{}", store.render());
    } else {
        anyhow::ensure!(!p.has_flag("warm"),
                        "--warm needs --store PATH");
    }
    Ok(())
}

fn cmd_repro(p: &Parsed) -> Result<()> {
    let dir = p.get_or("out-dir", "reports").to_string();
    let files = report::generate_all(Path::new(&dir))?;
    println!("wrote {} report files to {dir}/:", files.len());
    for f in files {
        println!("  {f}");
    }
    Ok(())
}

fn cmd_native(p: &Parsed) -> Result<()> {
    let dir = p.get_or("artifacts-dir", "artifacts").to_string();
    let manifest = Manifest::load(Path::new(&dir))?;
    let runtime = Runtime::new()?;
    println!("PJRT platform: {}", runtime.platform());
    let runs = p.get_u64("runs")?.unwrap_or(10) as usize;

    let metas: Vec<_> = match (p.get("id"), p.get("role")) {
        (Some(id), _) => vec![manifest.by_id(id)
            .ok_or_else(|| anyhow::anyhow!("no artifact {id}"))?],
        (None, Some(role)) => manifest.by_role(role),
        (None, None) => manifest.artifacts.iter().collect(),
    };
    anyhow::ensure!(!metas.is_empty(), "no artifacts selected");

    let verify = p.has_flag("verify");
    let mut t = Table::new(if verify {
        vec!["artifact", "status"]
    } else {
        vec!["artifact", "best s", "GFLOP/s", "stable(5vs10)"]
    }).numeric();
    for meta in metas {
        let kernel = runtime.load(&manifest, meta)?;
        if verify {
            let status = match executor::verify_kernel(&kernel, 1e-3) {
                Ok(()) => "ok".to_string(),
                Err(e) => format!("FAIL: {e}"),
            };
            t.row(vec![meta.id.clone(), status]);
        } else {
            let m = executor::measure_kernel(&kernel, 2, runs)?;
            t.row(vec![
                meta.id.clone(),
                format!("{:.5}", m.measurement.best()),
                m.gflops.map(|g| format!("{g:.2}"))
                    .unwrap_or_else(|| "-".into()),
                format!("{}", m.measurement.stable(0.05)),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_serve(p: &Parsed) -> Result<()> {
    use std::time::Duration;

    use alpaka_rs::serve::{loadgen, QuarantinePolicy, RetryPolicy,
                           Serve, ServeConfig, ShedPolicy};

    let mut archs = Vec::new();
    for tok in p.get_or("archs", "knl,p100-nvlink").split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        archs.push(ArchId::parse(tok)
            .ok_or_else(|| anyhow::anyhow!("unknown arch {tok:?}"))?);
    }
    anyhow::ensure!(!archs.is_empty(), "need at least one arch");

    // Native shards: real artifacts when present, synthetic catalog
    // (host reference GEMM) otherwise — the load test always exercises
    // every shard family, including both named native shards. In model
    // mode the manifest must carry the model entry, so the source is
    // resolved by the model plane instead.
    let dir = p.get_or("artifacts-dir", "artifacts").to_string();
    let model_src = match p.get("model") {
        Some(d) => Some(loadgen::model_source(Path::new(d))?),
        None => None,
    };
    anyhow::ensure!(model_src.is_none() || !p.has_flag("overload"),
                    "--model runs its own plan loop (drop --overload)");
    let (native, artifact_ids) = match &model_src {
        Some((native, _)) => (native.clone(), Vec::new()),
        None => loadgen::native_config_or_synthetic(Path::new(&dir)),
    };

    let clients = match p.get_u64("sessions")?.unwrap_or(0) as usize {
        0 => p.get_u64("clients")?.unwrap_or(8) as usize,
        s => s,
    };
    let window = p.get_u64("window")?.unwrap_or(1).max(1) as usize;
    let requests = p.get_u64("requests")?.unwrap_or(64) as usize;
    let n = p.get_u64("n")?.unwrap_or(1024);
    let queue = p.get_u64("queue")?.unwrap_or(64) as usize;
    let shed = ShedPolicy::parse(p.get_or("shed", "none"))
        .ok_or_else(|| anyhow::anyhow!(
            "unknown shed policy (none|reject|expire)"))?;
    let quota = p.get_u64("quota")?.unwrap_or(0) as usize;
    let deadline_ms = p.get_u64("deadline-ms")?.unwrap_or(0);
    let chaos_seed = p.get_u64("chaos-seed")?.unwrap_or(0);
    let fault_rate: f64 = p.get_or("fault-rate", "0.1").parse()
        .map_err(|_| anyhow::anyhow!("--fault-rate must be a number"))?;
    anyhow::ensure!((0.0..=1.0).contains(&fault_rate),
                    "--fault-rate must be in [0, 1]");
    let retries = p.get_u64("retries")?.unwrap_or(1).max(1) as u32;
    let quarantine_after =
        p.get_u64("quarantine-after")?.unwrap_or(0) as u32;
    let trace_path = p.get("trace").map(str::to_string);
    let trace_cap = p.get_u64("trace-cap")?.unwrap_or(256) as usize;
    anyhow::ensure!(trace_path.is_none() || trace_cap > 0,
                    "--trace needs --trace-cap > 0");
    // A shed policy with nothing to shed on is a silent no-op — refuse
    // it instead of letting the user believe shedding is active.
    anyhow::ensure!(
        shed != ShedPolicy::RejectOverQuota || quota > 0,
        "--shed reject does nothing without --quota > 0");
    anyhow::ensure!(
        shed != ShedPolicy::ShedExpired || quota > 0 || deadline_ms > 0,
        "--shed expire does nothing without --quota > 0 or \
         --deadline-ms > 0");
    // Deadlines are attached per-request by the open-loop driver only;
    // the closed-loop path would silently ignore the flag.
    anyhow::ensure!(
        deadline_ms == 0 || p.has_flag("overload"),
        "--deadline-ms is only applied by --overload (the closed loop \
         attaches no per-request deadlines)");
    let mut cfg = ServeConfig {
        front_cap: queue,
        shard_cap: queue,
        max_batch: p.get_u64("max-batch")?.unwrap_or(8) as usize,
        cache_cap: p.get_u64("cache")?.unwrap_or(128) as usize,
        sim_threads: p.get_u64("sim-threads")?.unwrap_or(2) as usize,
        native: Some(native),
        native_threads: p.get_u64("native-threads")?.unwrap_or(4)
            as usize,
        shed,
        shard_quota: if quota == 0 { None } else { Some(quota) },
        tuning_store: p.get("tuning-store")
            .map(|s| Path::new(s).to_path_buf()),
        result_cache_path: p.get("result-cache")
            .map(|s| Path::new(s).to_path_buf()),
        result_cache_cap: p.get_u64("result-cache-cap")?
            .unwrap_or(1024) as usize,
        online_tune: p.has_flag("online-tune"),
        trace_cap: if trace_path.is_some() { trace_cap } else { 0 },
        ..ServeConfig::default()
    };
    anyhow::ensure!(
        cfg.result_cache_path.is_none() || cfg.cache_cap > 0,
        "--result-cache needs --cache > 0 (measurement semantics \
         re-execute everything)");
    // Self-healing knobs apply with or without chaos; the fault plan
    // itself only exists when a chaos seed was given (same recipe as
    // the chaos_serve bench, via loadgen::chaos_config).
    let chaos_plan = if chaos_seed != 0 {
        let (with_chaos, plan) = loadgen::chaos_config(
            cfg, chaos_seed, fault_rate, retries, quarantine_after);
        cfg = with_chaos;
        println!("chaos: seed {chaos_seed}, fault rate {fault_rate}, \
                  {retries} attempt(s), quarantine after \
                  {quarantine_after}");
        Some(plan)
    } else {
        cfg.retry = RetryPolicy { max_attempts: retries,
                                  ..RetryPolicy::default() };
        cfg.quarantine = QuarantinePolicy { threshold: quarantine_after,
                                            ..QuarantinePolicy::default() };
        None
    };
    let serve = Serve::start(cfg.clone())?;

    // Model mode: drive whole plans (the fused serving tier) through
    // one session instead of the mixed item load. Self-healing, trace
    // and tuning knobs all apply unchanged — a plan node is an
    // ordinary request.
    if let Some((_, spec)) = &model_src {
        use alpaka_rs::model::{ModelPlan, Tier};

        let rate = p.get_f64("model-rate")?.unwrap_or(0.0);
        anyhow::ensure!(rate >= 0.0, "--model-rate must be >= 0");
        let plan = ModelPlan::compile(spec, Tier::Fused);
        println!("model serve: {} plan(s) of {} ({} tier, {} \
                  nodes/plan){}",
                 requests, spec.id, plan.tier.label(), plan.len(),
                 if rate > 0.0 {
                     format!(", open-loop at {rate:.1} plans/s")
                 } else {
                     ", closed-loop".to_string()
                 });
        let out = loadgen::run_model_loop(&serve, &plan, requests, rate);
        print!("{}", loadgen::model_report(&out, &plan));
        println!("{}", serve.summary());
        if let Some(cp) = &chaos_plan {
            print!("{}", loadgen::fault_report(cp));
        }
        if let Some(store) = serve.tuning_store() {
            if let Ok(g) = store.lock() {
                print!("{}", g.render());
            }
        }
        let recorder = serve.trace_recorder();
        serve.shutdown();
        if let (Some(path), Some(rec)) = (&trace_path, &recorder) {
            let n = loadgen::write_chrome_trace(rec, Path::new(path))?;
            println!("trace: wrote {n} trace(s) to {path}");
        }
        anyhow::ensure!(out.fully_accounted(plan.len()),
                        "model node accounting leak");
        anyhow::ensure!(chaos_plan.is_some() || out.nodes_failed == 0,
                        "{} model nodes failed: {:?}",
                        out.nodes_failed, out.first_failure);
        return Ok(());
    }

    let items = loadgen::default_mix(&archs, &artifact_ids, n);
    if p.has_flag("overload") {
        // Open loop at a fixed rate: first measure the sustainable rate
        // with a short closed loop on a SEPARATE, shed-free instance —
        // probing the quota-limited serve would deflate the measured
        // rate and pollute the overload run's reported metrics.
        let probe_serve = Serve::start(ServeConfig {
            shed: ShedPolicy::None,
            shard_quota: None,
            // the probe must not race the real layer for the store
            // file or double-explore buckets — nor spill probe
            // results into the real layer's persistent result cache
            tuning_store: None,
            online_tune: false,
            result_cache_path: None,
            // the probe must not advance the chaos plan's seeded
            // streams (it would desync replay) nor fail probe traffic
            fault_plan: None,
            // probe traffic must not pollute the exported traces
            trace_cap: 0,
            ..cfg.clone()
        })?;
        let sustainable = loadgen::measure_sustainable_rps(
            &probe_serve, &items, clients.min(4), 16);
        probe_serve.shutdown();
        let rate = match p.get_u64("rate")?.unwrap_or(0) {
            0 => 4.0 * sustainable,
            r => r as f64,
        };
        println!("overload: sustainable ~{sustainable:.0} req/s, \
                  offering {rate:.0} req/s open-loop \
                  (shed={}, quota={quota}, deadline={deadline_ms}ms)",
                 shed.label());
        let spec = loadgen::OverloadSpec {
            rate_rps: rate,
            total: clients * requests,
            items,
            deadline: if deadline_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(deadline_ms))
            },
        };
        let out = loadgen::run_open_loop(&serve, &spec);
        println!("{} submitted = {} ok + {} shed + {} closed + {} \
                  failed in {:.3}s", out.submitted, out.ok, out.shed,
                 out.closed, out.failed, out.wall_seconds);
        for (shard, count) in &out.per_shard {
            println!("  {shard}: {count} served");
        }
        println!("{}", serve.summary());
        if let Some(store) = serve.tuning_store() {
            if let Ok(g) = store.lock() {
                print!("{}", g.render());
            }
        }
        if let Some(plan) = &chaos_plan {
            print!("{}", loadgen::fault_report(plan));
        }
        // keep the recorder past shutdown so traces committed by the
        // drain (cancelled in-flight requests) make the export
        let recorder = serve.trace_recorder();
        serve.shutdown();
        if let (Some(path), Some(rec)) = (&trace_path, &recorder) {
            let n = loadgen::write_chrome_trace(rec, Path::new(path))?;
            println!("trace: wrote {n} trace(s) to {path}");
        }
        anyhow::ensure!(out.fully_accounted(), "reply accounting leak");
        // Under chaos, post-retry failures are expected (and visible
        // above); the hard invariant stays exact accounting.
        anyhow::ensure!(chaos_plan.is_some() || out.failed == 0,
                        "{} requests failed: {:?}",
                        out.failed, out.errors);
        return Ok(());
    }

    let spec = loadgen::LoadSpec {
        clients,
        requests_per_client: requests,
        items,
    };
    println!("serve load: {clients} session(s) x {requests} requests \
              (window {window}) over {} sim shard(s) + 2 native \
              shards, mix of {} items",
             archs.len(), spec.items.len());
    let outcome = loadgen::run_stream_loop(&serve, &spec, window);
    print!("{}", loadgen::outcome_report(&outcome, &serve));
    if let Some(plan) = &chaos_plan {
        print!("{}", loadgen::fault_report(plan));
    }
    if let Some(store) = serve.tuning_store() {
        if let Ok(g) = store.lock() {
            print!("{}", g.render());
        }
    }
    let recorder = serve.trace_recorder();
    serve.shutdown();
    if let (Some(path), Some(rec)) = (&trace_path, &recorder) {
        let n = loadgen::write_chrome_trace(rec, Path::new(path))?;
        println!("trace: wrote {n} trace(s) to {path}");
    }
    // Under chaos, post-retry failures are expected (and reported
    // above); exact accounting is enforced per session by the driver.
    anyhow::ensure!(chaos_plan.is_some() || outcome.failed == 0,
                    "{} requests failed", outcome.failed);
    Ok(())
}

fn cmd_model(p: &Parsed) -> Result<()> {
    use alpaka_rs::model::{ModelPlan, Tier};
    use alpaka_rs::serve::{loadgen, Serve, ServeConfig};

    let dir = p.get("dir")
        .or_else(|| p.positional.first().map(String::as_str))
        .unwrap_or("artifacts");
    let (native, spec) = loadgen::model_source(Path::new(dir))?;
    let repeat = p.get_u64("repeat")?.unwrap_or(3).max(1) as usize;
    let serve = Serve::start(ServeConfig {
        native: Some(native),
        native_threads: p.get_u64("native-threads")?.unwrap_or(4)
            as usize,
        // measurement semantics: re-execute every plan so the
        // per-layer means are honest, never cache replays
        cache_cap: 0,
        ..ServeConfig::default()
    })?;
    println!("model {}: batch {}, {} -> {} -> {}, {} layer(s)",
             spec.id, spec.dims.batch, spec.dims.d_in,
             spec.dims.d_hidden, spec.dims.d_out, spec.layers.len());
    // Fused is the serving tier; unfused shows what the epilogue
    // fusion buys; strict is the sequential bit-parity reference.
    for tier in [Tier::Fused, Tier::Unfused, Tier::Strict] {
        let plan = ModelPlan::compile(&spec, tier);
        let out = loadgen::run_model_loop(&serve, &plan, repeat, 0.0);
        print!("{}", loadgen::model_report(&out, &plan));
        anyhow::ensure!(
            out.nodes_failed == 0 && out.nodes_skipped == 0,
            "{} tier failed: {:?}", tier.label(), out.first_failure);
    }
    println!("{}", serve.summary());
    serve.shutdown();
    Ok(())
}

fn cmd_trace(p: &Parsed) -> Result<()> {
    use alpaka_rs::serve::trace;

    let path = p.get("input")
        .or_else(|| p.positional.first().map(String::as_str))
        .ok_or_else(|| anyhow::anyhow!(
            "need a trace JSON path (positional or --input) — \
             `serve --trace PATH` writes one"))?;
    let top = p.get_u64("top")?.unwrap_or(5).max(1) as usize;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let records = trace::parse_chrome_trace(&text)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    anyhow::ensure!(!records.is_empty(),
                    "{path} holds no serve traces");
    let failed = records.iter().filter(|r| r.failed()).count();
    println!("{}: {} trace(s), {failed} failed; slowest {}:", path,
             records.len(), top.min(records.len()));
    print!("{}", trace::waterfall(&records, top));
    Ok(())
}

fn cmd_inspect(p: &Parsed) -> Result<()> {
    let dir = p.get_or("artifacts-dir", "artifacts").to_string();
    let manifest = Manifest::load(Path::new(&dir))?;
    let id = p.get_or("id", "gemm_n128_t16_e1_f32");
    let meta = manifest.by_id(id)
        .ok_or_else(|| anyhow::anyhow!("no artifact {id}"))?;
    let hlo = std::fs::read_to_string(manifest.hlo_path(meta))?;
    let dots = hlo.matches(" dot(").count()
        + hlo.matches(" dot.").count();
    let whiles = hlo.matches("while(").count()
        + hlo.matches(" while").count();
    let fusions = hlo.matches("fusion").count();
    println!("artifact {id}: {} bytes of HLO", hlo.len());
    println!("  dot ops: {dots}  while loops: {whiles}  \
              fusions: {fusions}");
    println!("  (the Pallas/Alpaka abstraction is gone — only HLO \
              remains, cf. paper Listing 1.2)");
    for line in hlo.lines().filter(|l| l.contains("dot")).take(5) {
        println!("  | {}", line.trim());
    }
    Ok(())
}

fn cmd_lint(p: &Parsed) -> Result<()> {
    use alpaka_rs::analysis;

    // the manifest dir is the repo root (rust/src + examples live
    // under it), so a plain `alpaka-bench lint` checks this crate
    let root = p.get("root")
        .map(|s| Path::new(s).to_path_buf())
        .unwrap_or_else(|| {
            Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf()
        });
    let report = analysis::lint_tree(&root)
        .map_err(|e| anyhow::anyhow!("lint: {e}"))?;
    print!("{}", report.render());
    if let Some(path) = p.get("json") {
        std::fs::write(path, report.to_json())?;
        eprintln!("lint report written to {path}");
    }
    if let Some(path) = p.get("graph") {
        std::fs::write(path, &report.dot)?;
        eprintln!("call graph (DOT) written to {path}");
    }
    if p.has_flag("deny") && !report.is_clean() {
        anyhow::bail!("pallas-lint: {} diagnostic(s) (deny mode)",
                      report.diagnostics.len());
    }
    Ok(())
}
