//! Auto-tuning strategies vs the paper's exhaustive grid — the paper's
//! outlook ("may also enable auto-tuning") quantified: how many model
//! evaluations does each strategy need to find the grid optimum?
//!
//! Run with: `cargo run --release --offline --example autotune`

use alpaka_rs::arch::{compiler, ArchId};
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::sim::Machine;
use alpaka_rs::tuner::{tune_with, Strategy, TuningSpace};
use alpaka_rs::util::table::Table;

fn main() {
    let mut t = Table::new(vec!["arch", "precision", "strategy",
                                "evals", "found GF/s", "grid GF/s",
                                "found optimum?"]).numeric();
    for arch in [ArchId::Knl, ArchId::Power8, ArchId::P100Nvlink] {
        let comp = compiler::vendor_compiler(arch);
        for prec in Precision::ALL {
            let machine = Machine::for_arch(arch);
            let space = TuningSpace::paper(arch, comp, prec,
                                           GemmWorkload::TUNING_N);
            let grid = tune_with(Strategy::Grid, &machine, &space, 0, 1);
            for strat in [Strategy::Random, Strategy::HillClimb,
                          Strategy::Anneal] {
                // budget: half the grid
                let budget = (space.len() / 2).max(4);
                let out = tune_with(strat, &machine, &space, budget,
                                    0xBEEF);
                let hit = (out.best.gflops - grid.best.gflops).abs()
                    / grid.best.gflops < 0.01;
                t.row(vec![
                    arch.label().to_string(),
                    prec.dtype().to_string(),
                    strat.label().to_string(),
                    out.evals.to_string(),
                    format!("{:.0}", out.best.gflops),
                    format!("{:.0}", grid.best.gflops),
                    if hit { "yes".into() } else { "no".to_string() },
                ]);
            }
        }
    }
    println!("{}", t.render());
    println!("grid = the paper's exhaustive sweep (always optimal, \
              always full cost).");
}
