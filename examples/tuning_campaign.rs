//! The paper's full tuning campaign, §3: every architecture × compiler ×
//! precision, through the coordinator's scheduler, ending in the
//! Table-4 / Fig.-8 summaries.
//!
//! Run with: `cargo run --release --offline --example tuning_campaign`

use alpaka_rs::arch::{compiler, ArchId};
use alpaka_rs::coordinator::Scheduler;
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::sim::TuningPoint;
use alpaka_rs::tuner::{SweepResults, TuningSpace};
use alpaka_rs::util::table::Table;

fn main() {
    let workers = std::thread::available_parallelism()
        .map(|n| n.get()).unwrap_or(4);
    let sched = Scheduler::new(workers, 64);
    println!("== tuning campaign: {} workers ==\n", workers);

    let mut table = Table::new(vec![
        "architecture", "compiler", "precision", "best (T, h)",
        "GFLOP/s", "% of peak", "top-3 flatness",
    ]).numeric();

    for arch in ArchId::PAPER {
        for comp in compiler::valid_compilers(arch) {
            for prec in Precision::ALL {
                let space = TuningSpace::paper(arch, comp, prec,
                                               GemmWorkload::TUNING_N);
                let results = sched.run_batch(space.points());
                let mut sweep = SweepResults::default();
                for r in results {
                    sweep.push(r.record);
                }
                let best = sweep.best().expect("sweep non-empty");
                let flat = sweep.flatness(3)
                    .map(|f| format!("{f:.2}"))
                    .unwrap_or_else(|| "-".into());
                table.row(vec![
                    arch.label().to_string(),
                    comp.label().to_string(),
                    prec.dtype().to_string(),
                    format!("({}, {})", best.point.t,
                            best.point.hw_threads),
                    format!("{:.0}", best.gflops),
                    format!("{:.1}", 100.0 * best.relative_peak),
                    flat,
                ]);
            }
        }
    }
    println!("{}", table.render());
    println!("{}", sched.metrics.summary());

    // The paper's §3 control experiment: tuning at N=7168 must find the
    // same optima as N=10240 ("We don't see large deviations from our
    // tuning results for the control case N=7168").
    println!("\ncontrol case N = {} (paper §2.3):",
             GemmWorkload::CONTROL_N);
    let mut agree = 0;
    let mut total = 0;
    for arch in ArchId::PAPER {
        let comp = compiler::vendor_compiler(arch);
        for prec in Precision::ALL {
            let s1 = TuningSpace::paper(arch, comp, prec,
                                        GemmWorkload::TUNING_N);
            let s2 = TuningSpace::paper(arch, comp, prec,
                                        GemmWorkload::CONTROL_N);
            let b1 = best_of(&sched, s1);
            let b2 = best_of(&sched, s2);
            total += 1;
            if b1 == b2 {
                agree += 1;
            } else {
                println!("  {} {} {:?}: N=10240 -> {:?}, N=7168 -> {:?}",
                         arch.label(), comp.label(), prec, b1, b2);
            }
        }
    }
    println!("  optima agree for {agree}/{total} vendor-compiler \
              combinations");
}

fn best_of(sched: &Scheduler, space: TuningSpace) -> (u64, u64) {
    let results = sched.run_batch(space.points());
    let mut sweep = SweepResults::default();
    for r in results {
        sweep.push(r.record);
    }
    let b = sweep.best().expect("non-empty");
    (b.point.t, b.point.hw_threads)
}

#[allow(dead_code)]
fn unused(_: TuningPoint) {}
