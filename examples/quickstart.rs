//! Quickstart: the library in ~60 lines.
//!
//! 1. Ask the machine model what the paper's KNL would do at a tuning
//!    point, 2. run the paper's grid tuning for one combination, 3. print
//!    the Fig.-5 mapping of the optimum.
//!
//! Run with: `cargo run --release --offline --example quickstart`

use alpaka_rs::arch::{ArchId, CompilerId};
use alpaka_rs::gemm::{GemmWorkload, Precision};
use alpaka_rs::hierarchy::{map_gemm, mapping};
use alpaka_rs::sim::{Machine, TuningPoint};
use alpaka_rs::tuner::{self, TuningSpace};

fn main() {
    // --- 1. one prediction -------------------------------------------
    let machine = Machine::for_arch(ArchId::Knl);
    let point = TuningPoint::cpu(ArchId::Knl, CompilerId::Intel,
                                 Precision::F64,
                                 GemmWorkload::TUNING_N, 64, 1);
    let pred = machine.predict(&point);
    println!("KNL / Intel / f64 at (T=64, h=1):");
    println!("  {:.0} GFLOP/s = {:.1}% of peak ({:?}-bound)\n",
             pred.gflops, 100.0 * pred.relative_peak, pred.bound);

    // --- 2. the paper's multidimensional tuning ----------------------
    let space = TuningSpace::paper(ArchId::Knl, CompilerId::Intel,
                                   Precision::F64,
                                   GemmWorkload::TUNING_N);
    let results = tuner::sweep::grid_sweep_seq(&machine, &space);
    let best = results.best().expect("sweep is non-empty");
    println!("grid tuning over {} points finds (T={}, h={}) at \
              {:.0} GFLOP/s", space.len(), best.point.t,
             best.point.hw_threads, best.gflops);
    println!("paper Table 4 reports (T=64, h=1) at 510 GFLOP/s\n");

    // --- 3. the hierarchy mapping of that optimum (Fig. 5) -----------
    let backend = mapping::backend_for(ArchId::Knl);
    let m = map_gemm(backend, GemmWorkload::TUNING_N, best.point.t,
                     best.point.hw_threads)
        .expect("optimum is a legal mapping");
    println!("mapping: {}", m.describe());
}
