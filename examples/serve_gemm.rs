//! Serving example, ported to the unified serve layer: concurrent
//! clients drive simulated-architecture shards AND the native shard
//! through ONE front queue, with continuous batching, an LRU result
//! cache and unified metrics — the L3 coordinator in its router/batcher
//! role.
//!
//! Run with: `cargo run --release --offline --example serve_gemm`
//! (uses `artifacts/` when present, otherwise a synthetic native
//! catalog served by the host reference GEMM).

use std::path::Path;

use alpaka_rs::arch::ArchId;
use alpaka_rs::serve::{loadgen, Serve, ServeConfig};

fn main() -> alpaka_rs::Result<()> {
    let (native, artifact_ids) =
        loadgen::native_config_or_synthetic(Path::new("artifacts"));
    let serve = Serve::start(ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 128,
        sim_threads: 2,
        native: Some(native),
        ..ServeConfig::default()
    })?;

    println!("== unified serve layer: 6 clients x 12 requests over \
              4 shards ==\n");
    let spec = loadgen::LoadSpec {
        clients: 6,
        requests_per_client: 12,
        items: loadgen::default_mix(&[ArchId::Knl, ArchId::P100Nvlink],
                                    &artifact_ids, 1024),
    };
    let outcome = loadgen::run_closed_loop(&serve, &spec);
    print!("{}", loadgen::outcome_report(&outcome, &serve));
    println!("\nrequests were coalesced per work key (max batch {}) \
              while each backend stayed single-owner.",
             outcome.max_batch_seen);
    serve.shutdown();
    Ok(())
}
