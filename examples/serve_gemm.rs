//! Serving example over the **client plane**: sessions, futures and a
//! request pipeline driving the unified serve layer — the one
//! client-side concurrency idiom in the repo (no hand-rolled
//! threads-plus-channels here).
//!
//! Three acts:
//! 1. a [`Pipeline`] of chained GEMMs (`D = (A·B)·C` shaped) whose
//!    nodes auto-submit as their dependencies resolve;
//! 2. a [`Session::submit_stream`] pipelining independent requests
//!    through a bounded in-flight window, replies in completion order;
//! 3. the standard mixed closed loop (windowed sessions under the
//!    hood) with the per-session tallies in the summary.
//!
//! Run with: `cargo run --release --offline --example serve_gemm`
//! (uses `artifacts/` when present, otherwise a synthetic native
//! catalog served by the host GEMM).

use std::path::Path;

use alpaka_rs::arch::ArchId;
use alpaka_rs::client::{Pipeline, Session, SessionConfig,
                        WindowPolicy};
use alpaka_rs::serve::{loadgen, NativeEngineId, Serve, ServeConfig,
                       WorkItem};

fn main() -> alpaka_rs::Result<()> {
    let (native, artifact_ids) =
        loadgen::native_config_or_synthetic(Path::new("artifacts"));
    let serve = Serve::start(ServeConfig {
        front_cap: 64,
        shard_cap: 64,
        max_batch: 8,
        cache_cap: 128,
        sim_threads: 2,
        native: Some(native),
        ..ServeConfig::default()
    })?;

    // -- 1. chained GEMMs as a dependency pipeline --------------------
    let session = Session::open(&serve, SessionConfig {
        window: 4,
        on_full: WindowPolicy::Block,
        ..SessionConfig::default()
    });
    let first = artifact_ids[0].clone();
    let mut p = Pipeline::new();
    let ab = p.node(WorkItem::artifact(first.clone()), &[]);
    let abc = p.node(
        WorkItem::artifact_on(first.clone(), NativeEngineId::Threadpool),
        &[ab]);
    let d = p.node(WorkItem::artifact(first.clone()), &[ab, abc]);
    println!("== pipeline: D = (A·B)·C over session {} ==", session.id());
    let out = p.run(&session);
    for (i, r) in out.results.iter().enumerate() {
        match r {
            alpaka_rs::client::NodeResult::Ok(reply) => {
                println!("  node {i}: served by {} ({})", reply.shard,
                         reply.cache_src.label());
            }
            other => println!("  node {i}: {other:?}"),
        }
    }
    assert!(out.all_ok(), "pipeline failed: {:?}", out.result(d));

    // -- 2. a stream of independent requests, completion order --------
    let items: Vec<WorkItem> = (0..8)
        .map(|i| WorkItem::artifact(
            artifact_ids[i % artifact_ids.len()].clone()))
        .collect();
    println!("\n== stream: 8 requests through a window of 4 ==");
    for (idx, result) in session.submit_stream(items) {
        let reply = result.expect("stream reply");
        println!("  #{idx} <- {} ({}, batch {})", reply.shard,
                 reply.cache_src.label(), reply.batch_size);
    }
    let stats = session.close();
    assert!(stats.fully_accounted(), "{stats:?}");
    println!("session accounting: {stats:?}");

    // -- 3. the mixed closed loop (sessions under the hood) -----------
    println!("\n== unified serve layer: 6 clients x 12 requests over \
              4 shards ==\n");
    let spec = loadgen::LoadSpec {
        clients: 6,
        requests_per_client: 12,
        items: loadgen::default_mix(&[ArchId::Knl, ArchId::P100Nvlink],
                                    &artifact_ids, 1024),
    };
    let outcome = loadgen::run_closed_loop(&serve, &spec);
    print!("{}", loadgen::outcome_report(&outcome, &serve));
    println!("\nrequests were coalesced per work key (max batch {}) \
              while each backend stayed single-owner.",
             outcome.max_batch_seen);
    serve.shutdown();
    Ok(())
}
