//! Serving example: the GEMM service batching concurrent client
//! requests over the single-owner PJRT executor — the L3 coordinator in
//! its router/batcher role.
//!
//! Run with: `cargo run --release --offline --example serve_gemm`
//! (requires `make artifacts`)

use std::path::PathBuf;

use alpaka_rs::runtime::GemmService;
use alpaka_rs::util::stats::Summary;
use alpaka_rs::util::table::Table;

fn main() -> alpaka_rs::Result<()> {
    let svc = GemmService::start(PathBuf::from("artifacts"), 64, 8)?;
    println!("== GEMM service: 3 clients x 10 requests each ==\n");

    // warm the compile cache
    for id in ["dot_n128_f32", "dot_n256_f32", "gemm_n128_t16_e1_f32"] {
        svc.call(id)?;
    }

    // three "clients" submitting interleaved workloads
    let workloads = [
        ("client-a", "dot_n128_f32"),
        ("client-b", "dot_n256_f32"),
        ("client-c", "gemm_n128_t16_e1_f32"),
    ];
    let mut rxs = Vec::new();
    for round in 0..10 {
        for (client, id) in &workloads {
            rxs.push((*client, *id, round, svc.submit(id)));
        }
    }

    let mut t = Table::new(vec!["client", "artifact", "p50 exec ms",
                                "p50 queue ms", "max batch"]).numeric();
    for (client, id) in &workloads {
        let stats: Vec<_> = rxs.iter()
            .filter(|(c, i, _, _)| c == client && i == id)
            .collect();
        let mut execs = Vec::new();
        let mut queues = Vec::new();
        let mut max_batch = 0usize;
        for (_, _, _, rx) in stats {
            let s = rx.recv().expect("service alive")?;
            execs.push(s.seconds * 1e3);
            queues.push(s.queue_seconds * 1e3);
            max_batch = max_batch.max(s.batch_size);
        }
        t.row(vec![client.to_string(), id.to_string(),
                   format!("{:.3}", Summary::of(&execs).median),
                   format!("{:.3}", Summary::of(&queues).median),
                   max_batch.to_string()]);
    }
    println!("{}", t.render());
    println!("requests were coalesced per artifact (dynamic batching) \
              while the PJRT executor stayed single-owner.");
    svc.shutdown();
    Ok(())
}
