//! The KNL even-N anomaly study — paper §4/§5 including the 91-thread
//! verification experiment and the MCDRAM mode comparison.
//!
//! Run with: `cargo run --release --offline --example knl_anomaly`

use alpaka_rs::arch::{ArchId, CompilerId};
use alpaka_rs::gemm::Precision;
use alpaka_rs::sim::{Machine, MemMode, TuningPoint};
use alpaka_rs::util::table::Table;

fn main() {
    let machine = Machine::for_arch(ArchId::Knl);
    let point = |n, compiler, mode| TuningPoint {
        arch: ArchId::Knl,
        compiler,
        precision: Precision::F64,
        n,
        t: 64,
        hw_threads: 1,
        memmode: mode,
        thread_override: None,
    };

    println!("== KNL even-N anomaly (DP, T=64, h=1) ==\n");
    let mut t = Table::new(vec!["N", "Intel cached", "Intel flat",
                                "GNU cached", "drop?"]).numeric();
    for k in 6..=14u64 {
        let n = 1024 * k;
        let icc = machine.predict(&point(n, CompilerId::Intel,
                                         MemMode::Default)).gflops;
        let flat = machine.predict(&point(n, CompilerId::Intel,
                                          MemMode::KnlFlat)).gflops;
        let gnu = machine.predict(&point(n, CompilerId::Gnu,
                                         MemMode::Default)).gflops;
        let clean = machine.predict(&point(n - 1024 + 2048,
                                           CompilerId::Intel,
                                           MemMode::Default)).gflops;
        let _ = clean;
        let drop = n >= 8192 && n % 2048 == 0;
        t.row(vec![n.to_string(), format!("{icc:.0}"),
                   format!("{flat:.0}"), format!("{gnu:.0}"),
                   if drop { "yes".into() } else { String::new() }]);
    }
    println!("{}", t.render());
    println!("the drop appears with the Intel compiler in BOTH memory \
              modes and never with GNU — exactly the paper's Fig. 6 \
              pattern.\n");

    // the 91-thread experiment (paper §4: 490 instead of 303 GFLOP/s)
    let n = 8192;
    let with64 = machine.predict(&point(n, CompilerId::Intel,
                                        MemMode::Default));
    let with91 = machine.predict(
        &point(n, CompilerId::Intel, MemMode::Default)
            .with_thread_override(91));
    let neighbour = machine.predict(&point(9216, CompilerId::Intel,
                                           MemMode::Default));
    println!("N=8192, 64 threads: {:.0} GFLOP/s (paper: 303)",
             with64.gflops);
    println!("N=8192, 91 threads: {:.0} GFLOP/s (paper: 490)",
             with91.gflops);
    println!("N=9216 neighbour:   {:.0} GFLOP/s (paper: 527)",
             neighbour.gflops);

    // MCDRAM: cached vs flat vs DDR-only
    println!("\n== MCDRAM modes at N=10240 ==");
    for (mode, label) in [(MemMode::Default, "cached"),
                          (MemMode::KnlFlat, "flat (+2% per paper)"),
                          (MemMode::KnlDdrOnly, "DDR only")] {
        let p = machine.predict(&point(10240, CompilerId::Intel, mode));
        println!("  {label:<22} {:.0} GFLOP/s", p.gflops);
    }
}
