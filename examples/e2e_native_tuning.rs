//! END-TO-END DRIVER (experiment N1 in DESIGN.md): the full three-layer
//! stack on a real workload.
//!
//! This is the reproduction's existence proof that all layers compose:
//!
//! 1. loads the AOT artifacts (L1 Pallas kernel lowered through the L2
//!    JAX graph to HLO text by `make artifacts`),
//! 2. **verifies** every correctness-role artifact against the manifest
//!    digests (python-side numerics) — inputs regenerated bit-exactly in
//!    rust, no python anywhere on this path,
//! 3. runs the paper's §2 measurement protocol (max over 10 runs) for
//!    the native **tile-size sweep** — the Fig.-3 experiment on the
//!    sixth architecture (host CPU via PJRT, interpret-mode kernel),
//! 4. runs the **scaling series** (Fig. 6/7 analogue) at the tuned T,
//! 5. compares against the XLA-native `dot` baseline (the "vendor BLAS"
//!    of §2.1) and the MLP application graph,
//! 6. writes `reports/native_*.csv` and prints the tables that go into
//!    EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --offline --example e2e_native_tuning`

use std::path::Path;

use alpaka_rs::gemm::metrics;
use alpaka_rs::runtime::{executor, Manifest, Runtime};
use alpaka_rs::util::csvio::{Figure, Series};
use alpaka_rs::util::table::Table;

fn main() -> alpaka_rs::Result<()> {
    let artifacts = Path::new("artifacts");
    let reports = Path::new("reports");
    std::fs::create_dir_all(reports)?;
    let manifest = Manifest::load(artifacts)?;
    let runtime = Runtime::new()?;
    println!("== e2e native tuning on PJRT platform {:?} ==\n",
             runtime.platform());

    // ---- 2. digest verification over the correctness grid ----------
    let correctness = manifest.by_role("correctness");
    println!("verifying {} correctness artifacts against python \
              digests...", correctness.len());
    let mut failures = 0;
    for meta in &correctness {
        let kernel = runtime.load(&manifest, meta)?;
        match executor::verify_kernel(&kernel, 1e-3) {
            Ok(()) => println!("  {:<40} ok", meta.id),
            Err(e) => {
                failures += 1;
                println!("  {:<40} FAIL {e}", meta.id);
            }
        }
    }
    // the MLP application graph too
    for meta in manifest.by_role("application") {
        let kernel = runtime.load(&manifest, meta)?;
        match executor::verify_kernel(&kernel, 1e-3) {
            Ok(()) => println!("  {:<40} ok (application)", meta.id),
            Err(e) => {
                failures += 1;
                println!("  {:<40} FAIL {e}", meta.id);
            }
        }
    }
    assert_eq!(failures, 0, "digest verification failed");
    println!();

    // ---- 3. native tile sweep (paper Fig. 3, sixth architecture) ---
    let mut sweep = manifest.by_role("tile_sweep");
    sweep.sort_by_key(|m| (m.precision, m.t));
    let mut table = Table::new(vec!["artifact", "T", "dtype", "best s",
                                    "GFLOP/s", "stable"]).numeric();
    let mut fig = Figure::new("native tile sweep (host CPU, \
                               interpret-mode Pallas)", "tile size T",
                              "GFLOP/s");
    fig.log2_x = true;
    let mut best: Option<(u64, f64, String)> = None;
    let mut series_f32 = Series::new("pallas gemm f32 (N=256)");
    let mut series_f64 = Series::new("pallas gemm f64 (N=256)");
    for meta in &sweep {
        let kernel = runtime.load(&manifest, meta)?;
        let m = executor::measure_kernel(&kernel, 2, 10)?;
        let g = m.gflops.expect("gemm artifacts carry flops");
        let t = meta.t.expect("square tile");
        table.row(vec![meta.id.clone(), t.to_string(),
                       meta.precision.dtype().to_string(),
                       format!("{:.5}", m.measurement.best()),
                       format!("{g:.3}"),
                       format!("{}", m.measurement.stable(0.10))]);
        match meta.precision {
            alpaka_rs::gemm::Precision::F32 =>
                series_f32.push(t as f64, g),
            alpaka_rs::gemm::Precision::F64 =>
                series_f64.push(t as f64, g),
        }
        if meta.precision == alpaka_rs::gemm::Precision::F32
            && best.as_ref().map(|b| g > b.1).unwrap_or(true)
        {
            best = Some((t, g, meta.id.clone()));
        }
    }
    fig.add(series_f32);
    fig.add(series_f64);
    fig.write(reports, "native_tile_sweep")?;
    println!("{}", table.render());
    let (best_t, best_g, _) = best.expect("sweep non-empty");
    println!("tuned native optimum: T={best_t} at {best_g:.3} GFLOP/s \
              (written to reports/native_tile_sweep.csv)\n");

    // ---- 4. scaling series at tuned T + element-layer ablation -----
    let mut fig_scale = Figure::new("native scaling (host CPU)",
                                    "matrix size N", "GFLOP/s");
    let mut s_pallas = Series::new("pallas gemm f32 (T=32)");
    let mut s_base = Series::new("xla dot baseline f32");
    let mut scaling = manifest.by_role("scaling");
    scaling.sort_by_key(|m| m.n);
    for meta in &scaling {
        let kernel = runtime.load(&manifest, meta)?;
        let m = executor::measure_kernel(&kernel, 1, 5)?;
        s_pallas.push(meta.n.unwrap() as f64, m.gflops.unwrap());
    }
    let mut baselines = manifest.by_role("baseline");
    baselines.sort_by_key(|m| m.n);
    for meta in baselines.iter()
        .filter(|m| m.precision == alpaka_rs::gemm::Precision::F32)
    {
        let kernel = runtime.load(&manifest, meta)?;
        let m = executor::measure_kernel(&kernel, 1, 5)?;
        s_base.push(meta.n.unwrap() as f64, m.gflops.unwrap());
    }
    // who wins by how much at the largest common N (expected: the
    // interpret-mode kernel loses big — that factor is the documented
    // cost of interpret=True, see EXPERIMENTS.md §N1)
    let gap = s_base.points.last().unwrap().1
        / s_pallas.points.last().unwrap().1;
    fig_scale.add(s_pallas);
    fig_scale.add(s_base);
    fig_scale.write(reports, "native_scaling")?;
    println!("scaling series written to reports/native_scaling.csv");
    println!("XLA-dot baseline vs interpret-mode Pallas at N=512: \
              {gap:.0}x\n");

    // ---- element-layer ablation ------------------------------------
    let mut tbl = Table::new(vec!["artifact", "e", "GFLOP/s"]).numeric();
    for meta in manifest.by_role("element_sweep") {
        let kernel = runtime.load(&manifest, meta)?;
        let m = executor::measure_kernel(&kernel, 1, 5)?;
        tbl.row(vec![meta.id.clone(),
                     meta.n_e.unwrap_or(1).to_string(),
                     format!("{:.3}", m.gflops.unwrap())]);
    }
    println!("{}", tbl.render());

    // ---- headline sanity: Eq. 4 consistency -------------------------
    // (manifest flops match Eq. 2 for square gemms)
    for meta in &sweep {
        let n = meta.n.unwrap();
        assert_eq!(meta.flops.unwrap(), metrics::flops(n),
                   "{}: manifest flops must equal Eq. 2", meta.id);
    }
    println!("e2e native tuning complete — all layers compose.");
    Ok(())
}
