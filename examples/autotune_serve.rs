//! Online-autotuning example: start a serve layer with `--online-tune`
//! semantics, drive mixed shapes, and watch the layer LEARN — cold
//! requests run default kernel params while background exploration
//! jobs measure the real kernel and commit winners to the tuning
//! store; warm requests then serve with `…@store` params.
//!
//! Run with: `cargo run --release --offline --example autotune_serve`

use std::time::{Duration, Instant};

use alpaka_rs::serve::{loadgen, NativeConfig, NativeEngineId, Serve,
                       ServeConfig, WorkItem};

fn main() -> alpaka_rs::Result<()> {
    // Mixed shapes across three tuning buckets (64, 128, 256), served
    // on BOTH named native shards.
    let ids: Vec<String> = ["gemm_n64_t16_e1_f64", "dot_n128_f32",
                            "gemm_n256_t16_e1_f32"]
        .iter().map(|s| s.to_string()).collect();
    let store_path = std::env::temp_dir().join(format!(
        "alpaka_autotune_serve_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&store_path);

    let serve = Serve::start(ServeConfig {
        cache_cap: 0, // every request executes: watch the labels change
        native: Some(NativeConfig::Synthetic(ids.clone())),
        native_threads: 4,
        tuning_store: Some(store_path.clone()),
        online_tune: true,
        tune_budget: 4,
        tune_reps: 2,
        ..ServeConfig::default()
    })?;

    let mut items = Vec::new();
    for id in &ids {
        items.push(WorkItem::artifact(id.clone()));
        items.push(WorkItem::artifact_on(id.clone(),
                                         NativeEngineId::Threadpool));
    }

    println!("== phase 1: cold — defaults serve, exploration starts ==\n");
    let cold = loadgen::run_closed_loop(&serve, &loadgen::LoadSpec {
        clients: 4,
        requests_per_client: 6,
        items: items.clone(),
    });
    for (kernel, count) in &cold.per_kernel {
        println!("  {kernel}: {count}");
    }

    // Wait for the background explorations to commit (3 buckets).
    // Keep offering the mix meanwhile: explorations shed under the
    // tuner's line bound are retried by whichever later request finds
    // the bucket still untuned — that IS the retry mechanism.
    let store = serve.tuning_store().expect("online store");
    let t0 = Instant::now();
    while store.lock().unwrap().len() < 3
        && t0.elapsed() < Duration::from_secs(120)
    {
        for item in &items {
            let _ = serve.call(item.clone());
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    println!("\n{}", store.lock().unwrap().render());

    println!("== phase 2: warm — the same mix serves @store params ==\n");
    let warm = loadgen::run_closed_loop(&serve, &loadgen::LoadSpec {
        clients: 4,
        requests_per_client: 6,
        items,
    });
    print!("{}", loadgen::outcome_report(&warm, &serve));
    let tuned = warm.per_kernel.iter()
        .filter(|(k, _)| k.ends_with("@store"))
        .map(|(_, c)| c)
        .sum::<usize>();
    println!("\n{tuned}/{} native executions ran store-tuned params; \
              the store at {} survives restarts (rerun to see phase 1 \
              already warm).",
             warm.per_engine.values().sum::<usize>(),
             store_path.display());
    serve.shutdown();
    Ok(())
}
