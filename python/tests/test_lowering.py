"""Experiment L2a (paper Listing 1.2 analogue): the abstraction compiles
away. The paper disassembles its binary to show unrolled AVX-512 FMA; we
inspect the lowered HLO to show the Pallas/Alpaka-style abstraction
leaves only plain HLO: a `dot` (the MXU contraction) inside a `while`
loop (the grid), no python/Mosaic remnants."""

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels.gemm_tiled import square


def lower_text(spec):
    fn = model.gemm_model(spec)
    args = [jax.ShapeDtypeStruct((spec.m, spec.k), jnp.float32),
            jax.ShapeDtypeStruct((spec.k, spec.n), jnp.float32),
            jax.ShapeDtypeStruct((spec.m, spec.n), jnp.float32)]
    return aot.to_hlo_text(jax.jit(fn).lower(*args))


def test_abstraction_compiles_away():
    txt = lower_text(square(64, 16))
    assert "dot" in txt, "the tile contraction survives as an HLO dot"
    assert "while" in txt, "the grid became a loop"
    assert "custom-call" not in txt, "no Mosaic custom-calls (CPU path)"
    assert "pallas" not in txt.lower(), "no trace of the DSL"


def test_element_layer_changes_loop_not_interface():
    # different n_e: same entry signature, same output shape — only the
    # internal loop structure may differ (tuning is interface-invariant)
    t1 = lower_text(square(64, 16, n_e=1))
    t4 = lower_text(square(64, 16, n_e=4))
    for txt in (t1, t4):
        assert "f32[64,64]" in txt
        assert "ENTRY" in txt


def test_tile_size_reflected_in_dot_shape():
    txt = lower_text(square(64, 32))
    assert "f32[32,32]" in txt, "block-sized operands visible in HLO"


def test_baseline_is_a_single_dot():
    spec = square(64, 64)
    fn = model.gemm_baseline(spec)
    args = [jax.ShapeDtypeStruct((64, 64), jnp.float32)] * 3
    txt = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "dot" in txt
    assert "while" not in txt, "vendor-BLAS path has no grid loop"
