"""Hypothesis sweep over the MLP application graph — shapes, tiles and
dtypes, always against the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

_TOL = {"f32": dict(rtol=5e-4, atol=5e-5), "f64": dict(rtol=1e-10,
                                                       atol=1e-12)}


def _args(spec: model.MlpSpec, seed: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    d = jnp.float32 if spec.dtype == "f32" else jnp.float64
    shapes = [(spec.batch, spec.d_in), (spec.d_in, spec.d_hidden),
              (spec.d_hidden,), (spec.d_hidden, spec.d_out),
              (spec.d_out,)]
    return [jax.random.uniform(k, s, d, -0.5, 0.5)
            for k, s in zip(ks, shapes)]


@settings(max_examples=12, deadline=None)
@given(batch=st.sampled_from([16, 32, 64]),
       d_in=st.sampled_from([32, 64, 128]),
       d_hidden=st.sampled_from([32, 64]),
       d_out=st.sampled_from([16, 32]),
       t=st.sampled_from([16, 32]),
       dtype=st.sampled_from(["f32", "f64"]),
       seed=st.integers(0, 2**16))
def test_mlp_property(batch, d_in, d_hidden, d_out, t, dtype, seed):
    # all dims must be tileable by t
    if any(d % t for d in (batch, d_in, d_hidden, d_out)):
        t = 16
        if any(d % t for d in (batch, d_in, d_hidden, d_out)):
            return  # skip untileable draw
    spec = model.MlpSpec(batch=batch, d_in=d_in, d_hidden=d_hidden,
                         d_out=d_out, t=t, dtype=dtype)
    args = _args(spec, seed)
    out = model.mlp_forward(spec)(*args)
    want = ref.mlp_ref(*args)
    assert out.shape == (batch, d_out)
    np.testing.assert_allclose(out, want, **_TOL[dtype])


@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([16, 32, 64]), seed=st.integers(0, 100))
def test_mlp_tile_invariance(t, seed):
    # the application-level restatement of the paper's premise: the
    # internal tile size never changes the model's output
    base = model.MlpSpec(batch=64, d_in=64, d_hidden=64, d_out=64, t=64,
                         dtype="f64")
    tuned = model.MlpSpec(batch=64, d_in=64, d_hidden=64, d_out=64, t=t,
                          dtype="f64")
    args = _args(base, seed)
    np.testing.assert_allclose(model.mlp_forward(base)(*args),
                               model.mlp_forward(tuned)(*args),
                               rtol=1e-10)
