"""Core correctness signal: the single-source Pallas kernel vs the oracle.

Covers the full tuning-parameter space the way the paper sweeps it:
tile size T, element layer e, precision, alpha/beta — while the kernel
body stays untouched (checked by `test_kernel_is_single_source`).
"""

import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="hypothesis not installed in this image")
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_tiled, ref
from compile.kernels.gemm_tiled import GemmConfigError, GemmSpec, square

_TOL = {"f32": dict(rtol=3e-4, atol=3e-5), "f64": dict(rtol=1e-10, atol=1e-12)}


def run_spec(spec: GemmSpec, seed: int = 0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    dt = jnp.float32 if spec.dtype == "f32" else jnp.float64
    a = jax.random.uniform(keys[0], (spec.m, spec.k), dt, -1, 1)
    b = jax.random.uniform(keys[1], (spec.k, spec.n), dt, -1, 1)
    c = jax.random.uniform(keys[2], (spec.m, spec.n), dt, -1, 1)
    out = gemm_tiled.make_gemm(spec)(a, b, c)
    want = ref.gemm_ref(a, b, c, spec.alpha, spec.beta)
    np.testing.assert_allclose(out, want, **_TOL[spec.dtype])
    return out, (a, b, c)


# ---------------------------------------------------------------- direct --

@pytest.mark.parametrize("t", [4, 8, 16, 32, 64])
def test_tile_sweep_f32(t):
    run_spec(square(64, t, dtype="f32"))


@pytest.mark.parametrize("t", [4, 8, 16, 32])
def test_tile_sweep_f64(t):
    run_spec(square(32, t, dtype="f64"))


@pytest.mark.parametrize("e", [1, 2, 4, 8, 16])
def test_element_layer_sweep(e):
    # e is the paper's "elements per thread" axis: results must be
    # invariant under it (it only reshapes the reduction).
    spec = square(64, 16, n_e=e, dtype="f32")
    run_spec(spec)


@pytest.mark.parametrize("alpha,beta", [(1.0, 1.0), (0.0, 1.0), (1.0, 0.0),
                                        (1.5, 0.5), (-2.0, 3.25)])
def test_alpha_beta(alpha, beta):
    run_spec(square(32, 8, dtype="f64", alpha=alpha, beta=beta))


def test_rectangular_shapes_and_tiles():
    run_spec(GemmSpec(m=32, n=64, k=128, t_m=8, t_n=16, t_k=32))
    run_spec(GemmSpec(m=64, n=16, k=32, t_m=32, t_n=8, t_k=16, dtype="f64"))


def test_single_block_degenerate():
    # T == N: grid is 1x1x1, accumulator zeroed and flushed in one step.
    run_spec(square(16, 16))


def test_single_element_tiles():
    run_spec(square(8, 1))


def test_element_layer_invariance_bitwise_structure():
    # Same spec, different e: allclose to each other (not only to ref).
    spec1 = square(32, 16, n_e=1)
    spec4 = square(32, 16, n_e=4)
    out1, args = run_spec(spec1)
    out4 = gemm_tiled.make_gemm(spec4)(*args)
    np.testing.assert_allclose(out1, out4, rtol=1e-5, atol=1e-6)


def test_vs_naive_tiled_algorithm():
    # The kernel implements the paper's Fig. 2 algorithm, checked against a
    # literal numpy transcription (second, independent oracle).
    spec = square(48, 16, dtype="f64", alpha=1.25, beta=-0.5)
    out, (a, b, c) = run_spec(spec)
    naive = ref.gemm_naive_tiled(np.asarray(a), np.asarray(b), np.asarray(c),
                                 16, 1.25, -0.5)
    np.testing.assert_allclose(out, naive, rtol=1e-10)


# ------------------------------------------------------------- validation --

def test_invalid_tile_divisibility():
    with pytest.raises(GemmConfigError):
        square(100, 16).validate()


def test_invalid_element_layer():
    with pytest.raises(GemmConfigError):
        square(64, 16, n_e=3).validate()  # 3 does not divide 16


def test_invalid_dtype():
    with pytest.raises(GemmConfigError):
        square(64, 16, dtype="bf16").validate()


def test_invalid_nonpositive():
    with pytest.raises(GemmConfigError):
        GemmSpec(m=0, n=16, k=16, t_m=1, t_n=16, t_k=16).validate()


# ------------------------------------------------------------- properties --

_dims = st.sampled_from([8, 16, 32, 64])


@settings(max_examples=25, deadline=None)
@given(m=_dims, n=_dims, k=_dims,
       tm_div=st.sampled_from([1, 2, 4]), tn_div=st.sampled_from([1, 2, 4]),
       tk_div=st.sampled_from([1, 2, 4]),
       n_e=st.sampled_from([1, 2, 4]),
       dtype=st.sampled_from(["f32", "f64"]),
       alpha=st.floats(-2, 2), beta=st.floats(-2, 2),
       seed=st.integers(0, 2**16))
def test_property_kernel_matches_ref(m, n, k, tm_div, tn_div, tk_div, n_e,
                                     dtype, alpha, beta, seed):
    t_m, t_n, t_k = m // tm_div, n // tn_div, k // tk_div
    if t_k % n_e:
        n_e = 1
    spec = GemmSpec(m=m, n=n, k=k, t_m=t_m, t_n=t_n, t_k=t_k, n_e=n_e,
                    dtype=dtype, alpha=alpha, beta=beta)
    run_spec(spec, seed=seed)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([16, 32, 64]), t=st.sampled_from([4, 8, 16]))
def test_property_tile_size_invariance(n, t):
    # Tuning parameters must never change results — the paper's premise.
    a = jax.random.uniform(jax.random.PRNGKey(n * t), (n, n), jnp.float64)
    b = jax.random.uniform(jax.random.PRNGKey(n + t), (n, n), jnp.float64)
    c = jnp.zeros((n, n), jnp.float64)
    base = gemm_tiled.make_gemm(square(n, n, dtype="f64"))(a, b, c)
    tiled = gemm_tiled.make_gemm(square(n, t, dtype="f64"))(a, b, c)
    np.testing.assert_allclose(base, tiled, rtol=1e-10)


# ------------------------------------------------------ single-source-ness --

def test_kernel_is_single_source():
    """The kernel body must not branch on architecture/tuning identity:
    its free parameters are exactly the documented static ones."""
    sig = inspect.signature(gemm_tiled._gemm_kernel)
    kw = [p.name for p in sig.parameters.values()
          if p.kind == inspect.Parameter.KEYWORD_ONLY]
    assert sorted(kw) == ["alpha", "beta", "n_e", "n_k_grid"]
    src = inspect.getsource(gemm_tiled._gemm_kernel)
    body = src.split('"""')[-1]  # strip docstring ("output" contains "tpu")
    # no accelerator/dtype dispatch inside the body
    for token in ("cuda", "tpu", "float32", "float64", "backend"):
        assert token not in body


def test_working_set_accounting():
    spec = square(1024, 64, dtype="f64")
    # paper Eq. 5: K(S,T) = 2 T^2 S
    assert spec.tile_bytes() == 2 * 64 * 64 * 8
    assert spec.fits_vmem()
    big = square(8192, 2048, dtype="f64")
    assert not big.fits_vmem()


def test_grid_eq3():
    # paper Eq. 3: B(e,t) = N/(t*e) — here grid cells per dim = N/T.
    spec = square(256, 16)
    assert spec.grid() == (16, 16, 16)


def test_flops_eq2():
    # paper Eq. 2: O(N) = 3N^2 + 2N^3.
    spec = square(128, 16)
    assert spec.flops() == 2 * 128**3 + 3 * 128**2
