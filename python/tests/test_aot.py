"""AOT path: variant registry, HLO text lowering, manifest digests.

Also hosts the Listing-1.2 analogue (experiment L2a in DESIGN.md): the
paper disassembles the binary to prove the abstraction compiles away to
FMA vector code; we inspect the lowered/optimized HLO to prove the Pallas
abstraction compiles away to a fused dot inside a rolled loop.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, prng
from compile.kernels.gemm_tiled import square


def test_variant_registry_unique_ids():
    vs = aot.variants()
    ids = [v["id"] for v in vs]
    assert len(ids) == len(set(ids))
    assert len(vs) > 25
    roles = {v["role"] for v in vs}
    assert {"correctness", "tile_sweep", "element_sweep", "scaling",
            "baseline", "application"} <= roles


def test_gemm_id_format():
    assert aot.gemm_id(square(128, 16)) == "gemm_n128_t16_e1_f32"
    assert aot.gemm_id(square(128, 16, dtype="f64", alpha=1.5, beta=0.5)) \
        == "gemm_n128_t16_e1_f64_a1.5_b0.5"
    assert aot.gemm_id(square(64, 64), "dot") == "dot_n64_f32"


def test_hlo_text_lowering_roundtrip():
    spec = square(32, 8)
    fn = model.gemm_model(spec)
    lowered = jax.jit(fn).lower(
        *[jax.ShapeDtypeStruct(s, jnp.float32)
          for s in [(32, 32), (32, 32), (32, 32)]])
    txt = aot.to_hlo_text(lowered)
    assert "ENTRY" in txt and "f32[32,32]" in txt
    # interpret-mode pallas lowers the grid to a while loop + dynamic
    # slices — the whole abstraction is gone, only HLO ops remain.
    assert "while" in txt
    assert "dot(" in txt or "dot." in txt  # the MXU-shaped contraction


def test_digest_stats():
    out = np.arange(12, dtype=np.float32).reshape(3, 4)
    d = aot.digest(out, n_samples=4)
    assert d["shape"] == [3, 4]
    assert d["sum"] == pytest.approx(66.0)
    assert d["abs_sum"] == pytest.approx(66.0)
    assert d["samples"][0] == [0, 0.0] and d["samples"][-1] == [11, 11.0]


def test_gemm_inputs_deterministic():
    spec = square(16, 4)
    a1 = aot.gemm_inputs("x", spec)
    a2 = aot.gemm_inputs("x", spec)
    for x, y in zip(a1, a2):
        np.testing.assert_array_equal(x, y)
    b = aot.gemm_inputs("y", spec)
    assert not np.array_equal(a1[0], b[0])


def test_manifest_build_small(tmp_path):
    # End-to-end aot driver on a restricted variant set.
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--only",
                "gemm_n128_t16_e1_f32,dot_n128_f32"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["version"] == aot.MANIFEST_VERSION
    assert man["interchange"] == "hlo-text"
    ids = {e["id"] for e in man["artifacts"]}
    assert "gemm_n128_t16_e1_f32" in ids and "dot_n128_f32" in ids
    for e in man["artifacts"]:
        hlo = (tmp_path / e["file"]).read_text()
        assert "ENTRY" in hlo
        assert e["digest"]["shape"] == [128, 128]
        # digest must reproduce: rebuild inputs and re-run via jnp oracle
        if e["kind"] == "dot":
            a, b, c = aot.gemm_inputs(e["id"], square(128, 128))
            want = a @ b + c
            assert e["digest"]["sum"] == pytest.approx(
                float(np.asarray(want, np.float64).sum()), rel=1e-5)


def test_spec_meta_fields():
    v = {"kind": "gemm", "role": "correctness", "spec": square(128, 16)}
    meta = aot.spec_meta(v)
    assert meta["flops"] == 2 * 128**3 + 3 * 128**2
    assert meta["grid"] == [8, 8, 8]
    assert meta["tile_bytes"] == 2 * 16 * 16 * 4
