"""Cross-layer consistency: the python GemmSpec working-set accounting
must agree with the rust roofline module (rust/src/sim/roofline.rs keeps
the same 5*t^2*S formula) and with paper Eq. 5."""

from compile.kernels.gemm_tiled import GemmSpec, VMEM_BYTES, square


def test_eq5_tile_bytes():
    # paper Eq. 5: K(S,T) = 2 T^2 S
    assert square(1024, 64, dtype="f64").tile_bytes() == 2 * 64 * 64 * 8
    assert square(1024, 4, dtype="f32").tile_bytes() == 128  # Table 4 GPU


def test_vmem_is_five_tiles():
    # A + B + C-in + C-out + accumulator = 5 tiles (mirrored in
    # rust roofline::analyse)
    for t, dtype, s in [(64, "f32", 4), (128, "f64", 8)]:
        spec = square(1024, t, dtype=dtype)
        assert spec.vmem_bytes() == 5 * t * t * s


def test_vmem_budget_boundary():
    # largest f32 tile under the 16 MiB budget: 5*t^2*4 <= 16Mi
    # -> t <= 915; power-of-two boundary at 512
    assert square(4096, 512, dtype="f32").fits_vmem()
    assert not square(8192, 1024, dtype="f32").fits_vmem()
    assert VMEM_BYTES == 16 * 1024 * 1024


def test_rectangular_tile_bytes():
    spec = GemmSpec(m=128, n=64, k=256, t_m=32, t_n=16, t_k=64)
    # (t_m*t_k + t_k*t_n) * S
    assert spec.tile_bytes() == (32 * 64 + 64 * 16) * 4
