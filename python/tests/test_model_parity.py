"""Cross-language model KAT: the strict MLP tier, bit for bit.

``compile/modelref.py`` is the numpy twin of the rust strict tier; this
test pins its activation bit patterns and asserts the shared fixture
``rust/tests/fixtures/mlp_parity.json`` (asserted from the other side
by ``rust/tests/model_serve.rs``). The fixture stores IEEE-754 **bit
patterns** (u32), never decimal floats, so the comparison is exact:

* per layer, the u32-xor of every output element (order-independent,
  catches any single-bit drift anywhere in the tensor), plus 64 evenly
  spaced sampled elements compared individually (localizes a drift);
* the det_tanh / det_exp_neg known-answer bits (mirrored in
  ``rust/src/util/numerics.rs``).

Regenerate after an *intentional* numeric change with::

    python -m tests.test_model_parity

If this test fails, the *python* side drifted; if the rust twin fails,
the rust one did.
"""

import json
import os

import numpy as np

from compile import modelref, prng

_FIXTURE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..",
    "rust", "tests", "fixtures", "mlp_parity.json")

# The demo model served by rust without a manifest
# (rust/src/model demo_manifest_text) — also aot.py's MlpSpec default.
_MODEL_ID = "mlp_b64_f32"
_DIMS = dict(batch=64, d_in=256, d_hidden=128, d_out=64)
_SAMPLES = 64

# Bit pins mirrored by rust's known_answer_pins_cross_language_contract.
_TANH_1_BITS = 0x3FE85EFAB514F394
_TANH_HALF_BITS = 0x3FDD9353D7568AF3
_EXP_NEG1_BITS = 0x3FD78B56362CEF38


def _bits64(x):
    return int(np.asarray(x, dtype=np.float64).view(np.uint64))


def _layer_entry(out):
    bits = out.ravel().view(np.uint32)
    xor = 0
    for b in bits.tolist():
        xor ^= b
    idx = np.linspace(0, bits.size - 1, _SAMPLES).astype(int)
    return {
        "shape": list(out.shape),
        "xor_bits": xor,
        "sample_idx": idx.tolist(),
        "sample_bits": bits[idx].tolist(),
    }


def _payload():
    outs = modelref.mlp_forward_strict(_MODEL_ID, **_DIMS)
    return {
        "comment": "Cross-language strict-MLP parity fixture. Generated "
                   "by python/tests/test_model_parity.py from "
                   "compile/modelref.py; asserted bit-exactly by "
                   "rust/tests/model_serve.rs. Values are IEEE-754 bit "
                   "patterns (u32 per f32 element, u64 for the "
                   "activation pins).",
        "model": _MODEL_ID,
        "dims": _DIMS,
        "seeds": [prng.seed_for(_MODEL_ID, k) for k in range(5)],
        "tanh_pins": {
            "tanh_1": _TANH_1_BITS,
            "tanh_half": _TANH_HALF_BITS,
            "exp_neg1": _EXP_NEG1_BITS,
        },
        "layers": [_layer_entry(o) for o in outs],
    }


def test_activation_bit_pins():
    assert _bits64(modelref.det_tanh(1.0)) == _TANH_1_BITS
    assert _bits64(modelref.det_tanh(0.5)) == _TANH_HALF_BITS
    assert _bits64(modelref.det_exp_neg(-1.0)) == _EXP_NEG1_BITS
    # round-once f32 path
    t32 = modelref.det_tanh_f32(np.float32(1.0))
    want = np.asarray(modelref.det_tanh(1.0)).astype(np.float32)
    assert t32.view(np.uint32) == want.view(np.uint32)


def test_unfused_activation_equals_fused_bitwise():
    """act(preact) must equal the fused layer bitwise — the invariant
    that lets the rust unfused tier split GEMM and activation into
    separate plan nodes without changing a single output bit."""
    seeds = [prng.seed_for(_MODEL_ID, k) for k in range(5)]
    x = prng.matrix(seeds[0], _DIMS["batch"], _DIMS["d_in"], "f32")
    w1 = prng.matrix(seeds[1], _DIMS["d_in"], _DIMS["d_hidden"], "f32")
    b1 = prng.matrix(seeds[2], _DIMS["d_hidden"], 1, "f32").ravel()
    fused = modelref.gemm_strict_f32(x, w1, b1, 1.0, 1.0, activate=True)
    pre = modelref.gemm_strict_f32(x, w1, b1, 1.0, 1.0, activate=False)
    np.testing.assert_array_equal(
        modelref.det_tanh_f32(pre).view(np.uint32), fused.view(np.uint32))


def test_parity_fixture_matches_bit_for_bit():
    with open(_FIXTURE) as f:
        fixture = json.load(f)
    want = _payload()
    assert fixture["model"] == want["model"]
    assert fixture["seeds"] == want["seeds"]
    assert fixture["tanh_pins"] == want["tanh_pins"]
    assert len(fixture["layers"]) == len(want["layers"]) == 2
    for got, exp in zip(fixture["layers"], want["layers"]):
        assert got["shape"] == exp["shape"]
        assert got["sample_idx"] == exp["sample_idx"]
        assert got["sample_bits"] == exp["sample_bits"], \
            "sampled strict-layer elements drifted"
        assert got["xor_bits"] == exp["xor_bits"], \
            "full-tensor xor drifted (some element outside the samples)"


def test_tanh_is_odd_and_saturates():
    x = np.linspace(-25.0, 25.0, 301)
    y = modelref.det_tanh(x)
    np.testing.assert_array_equal(
        np.asarray(y).view(np.uint64),
        np.asarray(-modelref.det_tanh(-x)).view(np.uint64))
    assert float(modelref.det_tanh(21.0)) == 1.0
    assert float(modelref.det_tanh(-21.0)) == -1.0
    # close to libm (sanity anchor only — determinism is the contract)
    np.testing.assert_allclose(y, np.tanh(x), rtol=1e-14, atol=1e-300)


if __name__ == "__main__":
    payload = _payload()
    os.makedirs(os.path.dirname(_FIXTURE), exist_ok=True)
    with open(_FIXTURE, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(_FIXTURE)}")
