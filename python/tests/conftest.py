"""Shared pytest configuration: enable x64 before jax initializes."""

import os
import sys

# Make `compile` (python/compile) importable no matter where pytest is
# invoked from — the repo is not pip-installed.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

jax.config.update("jax_enable_x64", True)
