"""Shared pytest configuration: enable x64 before jax initializes."""

import jax

jax.config.update("jax_enable_x64", True)
