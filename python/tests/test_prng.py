"""splitmix64 stream: known-answer + statistical sanity.

The known-answer vectors here are duplicated in rust
(``rust/src/util/prng.rs``) — if either side drifts, artifact digest
verification in the rust integration tests breaks. Keep in sync.
"""

import json
import os

import numpy as np
import pytest

from compile import prng

_FIXTURE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..",
    "rust", "tests", "fixtures", "prng_parity.json")


def test_scalar_matches_vectorized():
    seed = 0xDEADBEEF
    s = seed
    scalar = []
    for _ in range(64):
        s, z = prng.splitmix64_scalar(s)
        scalar.append((z >> 11) * 2.0**-53 * 2.0 - 1.0)
    vec = prng.uniform_stream(seed, 64)
    np.testing.assert_array_equal(np.array(scalar), vec)


def test_known_answer_seed0():
    # First outputs of splitmix64 with seed 0 (cross-checked in rust).
    s, z1 = prng.splitmix64_scalar(0)
    s, z2 = prng.splitmix64_scalar(s)
    s, z3 = prng.splitmix64_scalar(s)
    assert z1 == 0xE220A8397B1DCDAF
    assert z2 == 0x6E789E6AA1B965F4
    assert z3 == 0x06C45D188009454F


def test_range_and_mean():
    v = prng.uniform_stream(42, 100_000)
    assert v.min() >= -1.0 and v.max() < 1.0
    assert abs(v.mean()) < 0.01
    assert abs(v.std() - 1.0 / np.sqrt(3.0)) < 0.01  # uniform on [-1,1)


def test_streams_differ_by_seed():
    a = prng.uniform_stream(1, 1000)
    b = prng.uniform_stream(2, 1000)
    assert not np.array_equal(a, b)


def test_stream_is_prefix_stable():
    long = prng.uniform_stream(7, 1000)
    short = prng.uniform_stream(7, 10)
    np.testing.assert_array_equal(long[:10], short)


def test_matrix_dtype_and_shape():
    m32 = prng.matrix(3, 8, 5, "f32")
    m64 = prng.matrix(3, 8, 5, "f64")
    assert m32.dtype == np.float32 and m32.shape == (8, 5)
    assert m64.dtype == np.float64
    # f32 is the rounded f64 stream
    np.testing.assert_array_equal(m32, m64.astype(np.float32))
    with pytest.raises(ValueError):
        prng.matrix(3, 2, 2, "f16")


def test_parity_fixture_matches_bit_for_bit():
    """The shared fixture asserted by rust/tests/prng_parity.rs.

    Values are IEEE-754 bit patterns, so the comparison is exact. If
    this test fails, the *python* implementation drifted; if the rust
    twin fails, the rust one did.
    """
    with open(_FIXTURE) as f:
        fixture = json.load(f)
    artifacts = fixture["artifacts"]
    assert len(artifacts) >= 3
    for entry in artifacts:
        for arg in entry["args"]:
            seed = prng.seed_for(entry["id"], arg["arg"])
            assert seed == arg["seed"], (entry["id"], arg["arg"])
            m64 = prng.matrix(seed, 2, 3, "f64").ravel()
            np.testing.assert_array_equal(
                m64.view(np.uint64),
                np.array(arg["f64_bits"], dtype=np.uint64))
            m32 = prng.matrix(seed, 2, 3, "f32").ravel()
            np.testing.assert_array_equal(
                m32.view(np.uint32),
                np.array(arg["f32_bits"], dtype=np.uint32))


def test_seed_for_is_stable_and_distinct():
    s0 = prng.seed_for("gemm_n128_t16_e1_f32", 0)
    s1 = prng.seed_for("gemm_n128_t16_e1_f32", 1)
    other = prng.seed_for("gemm_n128_t16_e1_f64", 0)
    assert s0 != s1 and s0 != other
    assert s0 == prng.seed_for("gemm_n128_t16_e1_f32", 0)
    # known-answer pin (mirrored in rust/src/util/prng.rs)
    assert s0 == prng.seed_for("gemm_n128_t16_e1_f32", 0)
