"""L2 model graphs: MLP application + baseline equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.kernels.gemm_tiled import square


def _mlp_args(spec: model.MlpSpec, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    d = jnp.float32 if spec.dtype == "f32" else jnp.float64
    shapes = [(spec.batch, spec.d_in), (spec.d_in, spec.d_hidden),
              (spec.d_hidden,), (spec.d_hidden, spec.d_out), (spec.d_out,)]
    return [jax.random.uniform(k, s, d, -0.5, 0.5)
            for k, s in zip(ks, shapes)]


def test_mlp_matches_ref_f32():
    spec = model.MlpSpec()
    args = _mlp_args(spec)
    out = model.mlp_forward(spec)(*args)
    want = ref.mlp_ref(*args)
    assert out.shape == (spec.batch, spec.d_out)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-5)


def test_mlp_matches_ref_f64():
    spec = model.MlpSpec(batch=32, d_in=64, d_hidden=32, d_out=32, t=16,
                         dtype="f64")
    args = _mlp_args(spec, seed=1)
    out = model.mlp_forward(spec)(*args)
    np.testing.assert_allclose(out, ref.mlp_ref(*args), rtol=1e-10)


def test_mlp_jits():
    spec = model.MlpSpec(batch=32, d_in=32, d_hidden=32, d_out=32, t=16)
    args = _mlp_args(spec, seed=2)
    eager = model.mlp_forward(spec)(*args)
    jitted = jax.jit(model.mlp_forward(spec))(*args)
    np.testing.assert_allclose(eager, jitted, rtol=1e-6)


def test_gemm_specs_divisibility():
    g1, g2 = model.MlpSpec().gemm_specs()
    g1.validate()
    g2.validate()
    assert g1.beta == 1.0  # bias flows through the beta*C term


def test_baseline_equals_kernel():
    spec = square(64, 16, alpha=0.5, beta=1.5)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    a, b, c = (jax.random.uniform(k, (64, 64), jnp.float32, -1, 1)
               for k in ks)
    kern = model.gemm_model(spec)(a, b, c)
    base = model.gemm_baseline(spec)(a, b, c)
    np.testing.assert_allclose(kern, base, rtol=3e-4, atol=3e-5)


@pytest.mark.parametrize("batch,t", [(16, 16), (64, 32)])
def test_mlp_batch_variants(batch, t):
    spec = model.MlpSpec(batch=batch, d_in=64, d_hidden=64, d_out=32, t=t)
    args = _mlp_args(spec, seed=batch)
    out = model.mlp_forward(spec)(*args)
    np.testing.assert_allclose(out, ref.mlp_ref(*args), rtol=3e-4,
                               atol=3e-5)
