"""Deterministic input generation shared bit-exactly with the rust side.

The rust integration tests re-generate the very same matrices (see
``rust/src/util/prng.rs``) so artifact outputs can be verified against the
digests recorded in ``artifacts/manifest.json`` without python on the
request path.

Stream definition (splitmix64):

    state_{i} = (seed + i * 0x9E3779B97F4A7C15) mod 2^64   for i = 1, 2, ...
    z = state; z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9
               z = (z ^ (z >> 27)) * 0x94D049BB133111EB
               z = z ^ (z >> 31)
    value_i = (z >> 11) * 2^-53 * 2 - 1        # f64 in [-1, 1)

f32 inputs are the f64 value rounded once to f32 — identical in numpy
(`astype(float32)`) and rust (`as f32`), both IEEE round-to-nearest-even.
"""

from __future__ import annotations

import numpy as np

GOLDEN = 0x9E3779B97F4A7C15
MIX1 = 0xBF58476D1CE4E5B9
MIX2 = 0x94D049BB133111EB
MASK = (1 << 64) - 1


def splitmix64_scalar(state: int) -> tuple[int, int]:
    """One step of splitmix64. Returns (new_state, output). Reference/teaching
    implementation; the vectorized `uniform_stream` is what production uses."""
    state = (state + GOLDEN) & MASK
    z = state
    z = ((z ^ (z >> 30)) * MIX1) & MASK
    z = ((z ^ (z >> 27)) * MIX2) & MASK
    z = z ^ (z >> 31)
    return state, z


def uniform_stream(seed: int, count: int) -> np.ndarray:
    """Vectorized stream of `count` f64 values in [-1, 1)."""
    with np.errstate(over="ignore"):
        i = np.arange(1, count + 1, dtype=np.uint64)
        state = np.uint64(seed & MASK) + i * np.uint64(GOLDEN)
        z = state
        z = (z ^ (z >> np.uint64(30))) * np.uint64(MIX1)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(MIX2)
        z = z ^ (z >> np.uint64(31))
    return (z >> np.uint64(11)).astype(np.float64) * 2.0**-53 * 2.0 - 1.0


def matrix(seed: int, rows: int, cols: int, dtype: str) -> np.ndarray:
    """Deterministic (rows, cols) matrix for the given dtype ('f32'|'f64')."""
    vals = uniform_stream(seed, rows * cols).reshape(rows, cols)
    if dtype == "f32":
        return vals.astype(np.float32)
    if dtype == "f64":
        return vals
    raise ValueError(f"unsupported dtype {dtype!r}")


def seed_for(artifact_id: str, arg_index: int) -> int:
    """Stable per-(artifact, argument) seed: FNV-1a over the id, xor arg.

    Mirrored in rust (util::prng::seed_for)."""
    h = 0xCBF29CE484222325
    for byte in artifact_id.encode("utf-8"):
        h = ((h ^ byte) * 0x100000001B3) & MASK
    return h ^ (0x9E3779B9 * (arg_index + 1) & MASK)
