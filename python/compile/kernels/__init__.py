"""Layer 1 — Pallas kernels.

``gemm_tiled`` holds THE single-source tiled GEMM kernel of the
reproduction (paper sec. 2.1); ``ref`` holds the pure-jnp / numpy oracles
used by pytest at build time.
"""
