"""Correctness oracles for the tiled GEMM kernel.

Two independent references:

* ``gemm_ref`` — pure jnp, one fused expression; the oracle pytest compares
  the Pallas kernel against (and the "vendor BLAS" stand-in the paper's
  §2.1 alludes to when citing 90 %-of-peak DGEMM implementations).
* ``gemm_naive_tiled`` — numpy triple-tile-loop mirroring the paper's
  Fig. 2 algorithm literally. Used on small sizes to validate that the
  *algorithm* (tiling + streaming C) is what the kernel computes, not just
  the final linear-algebra identity.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def gemm_ref(a, b, c, alpha: float = 1.0, beta: float = 1.0):
    """alpha * a @ b + beta * c with accumulation at operand precision."""
    return alpha * jnp.dot(a, b, preferred_element_type=a.dtype) + beta * c


def gemm_naive_tiled(a: np.ndarray, b: np.ndarray, c: np.ndarray,
                     t: int, alpha: float = 1.0,
                     beta: float = 1.0) -> np.ndarray:
    """Literal transcription of the paper's Fig. 2 tiling strategy.

    For every (t x t) tile of C: iterate over the K/t tile pairs of A and
    B, accumulate their product into a local C tile, then write
    ``alpha * acc + beta * C`` back — C is streamed exactly once.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    assert m % t == 0 and n % t == 0 and k % t == 0
    out = np.empty_like(c)
    for i0 in range(0, m, t):
        for j0 in range(0, n, t):
            acc = np.zeros((t, t), dtype=a.dtype)
            for k0 in range(0, k, t):
                acc += a[i0:i0 + t, k0:k0 + t] @ b[k0:k0 + t, j0:j0 + t]
            out[i0:i0 + t, j0:j0 + t] = alpha * acc + beta * c[i0:i0 + t,
                                                               j0:j0 + t]
    return out


def mlp_ref(x, w1, b1, w2, b2):
    """Two-layer tanh MLP, pure jnp — oracle for model.mlp_forward."""
    h = jnp.tanh(jnp.dot(x, w1, preferred_element_type=x.dtype) + b1)
    return jnp.dot(h, w2, preferred_element_type=x.dtype) + b2
