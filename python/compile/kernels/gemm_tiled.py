"""Layer 1 — the single-source tiled GEMM Pallas kernel (paper §2.1).

The paper's central claim is that ONE kernel source can be tuned for many
architectures purely through parameters that live *outside* the kernel:
tile size ``T``, elements-per-thread ``e`` (the "element layer"), hardware
threads. This module is the transplant of that claim onto the Pallas
programming model:

* ``_gemm_kernel`` below is written ONCE and never specialized. Everything
  an architecture tune would change — C-tile shape ``(t_m, t_n)``,
  reduction-tile depth ``t_k``, element-layer split ``n_e`` — enters only
  through ``pl.BlockSpec``/grid parameters and static keyword arguments,
  i.e. the Alpaka ``OptimalVectorSize`` trait of Listing 1.1 re-expressed
  as a variant factory (`make_gemm`).

* The hierarchy mapping (paper Fig. 1 / Fig. 5):

  ========================  =====================================
  Alpaka layer              Pallas realization
  ========================  =====================================
  grid of blocks            ``grid = (M/t_m, N/t_n, K/t_k)``
  block (computes C tile)   one grid cell, C block ``(t_m, t_n)``
  threads in block          vector lanes of the in-kernel ``dot``
  element layer             ``n_e`` chunks of the k-reduction,
                            iterated by a fori_loop (enables the
                            vector unit to stream, paper Fig. 2)
  shared/L1 tile residency  VMEM residency of A/B blocks
  ========================  =====================================

* Accumulation across the ``k`` grid dimension happens in a VMEM scratch
  accumulator (``acc_ref``), zeroed at ``k == 0`` and flushed as
  ``alpha * acc + beta * C`` at the last k step — exactly the paper's
  "thread-local C tile" streaming strategy (Fig. 2): C itself is read and
  written once.

Kernels here MUST be lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# --------------------------------------------------------------------------
# THE kernel. Single source — do not specialize per architecture. Tuning
# happens exclusively via the parameters of `make_gemm`.
# --------------------------------------------------------------------------


def _gemm_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, n_k_grid, n_e,
                 alpha, beta):
    """C_tile = alpha * sum_k A_tile(k) @ B_tile(k) + beta * C_tile.

    a_ref: (t_m, t_k) block of A      c_ref: (t_m, t_n) block of C (input)
    b_ref: (t_k, t_n) block of B      o_ref: (t_m, t_n) block of C (output)
    acc_ref: (t_m, t_n) VMEM scratch accumulator, live across the k grid.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    # Element layer: split the reduction into n_e chunks. For n_e == 1 this
    # is a single MXU-shaped dot; larger n_e expresses the paper's
    # "elements per thread" vector streaming without touching the body.
    t_k = a_ref.shape[1]
    chunk = t_k // n_e

    def body(i, carry):
        a = a_ref[:, pl.dslice(i * chunk, chunk)]
        b = b_ref[pl.dslice(i * chunk, chunk), :]
        acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)
        return carry

    jax.lax.fori_loop(0, n_e, body, 0)

    @pl.when(k == n_k_grid - 1)
    def _flush():
        o_ref[...] = (alpha * acc_ref[...] + beta * c_ref[...]).astype(
            o_ref.dtype)


# --------------------------------------------------------------------------
# Variant factory — the Alpaka `OptimalVectorSize` analogue.
# --------------------------------------------------------------------------

_DTYPES = {"f32": jnp.float32, "f64": jnp.float64}
_SIZEOF = {"f32": 4, "f64": 8}

#: VMEM budget of a TPU core in bytes; tile working sets are checked
#: against it like the paper checks K(S,T) against cache sizes (Eq. 5).
VMEM_BYTES = 16 * 1024 * 1024


class GemmConfigError(ValueError):
    """Raised for an invalid (shape, tile, element-layer) combination."""


@dataclass(frozen=True)
class GemmSpec:
    """A tuning point for the single-source kernel (everything *outside*
    the kernel body, per the paper's methodology)."""

    m: int
    n: int
    k: int
    t_m: int
    t_n: int
    t_k: int
    n_e: int = 1          # element layer split of the reduction tile
    dtype: str = "f32"
    alpha: float = 1.0
    beta: float = 1.0

    def validate(self) -> None:
        if self.dtype not in _DTYPES:
            raise GemmConfigError(f"dtype must be f32|f64, got {self.dtype}")
        for dim, tile, names in ((self.m, self.t_m, "m/t_m"),
                                 (self.n, self.t_n, "n/t_n"),
                                 (self.k, self.t_k, "k/t_k")):
            if dim <= 0 or tile <= 0:
                raise GemmConfigError(f"{names}: sizes must be positive")
            if dim % tile:
                raise GemmConfigError(
                    f"{names}: tile {tile} must divide dimension {dim}")
        if self.n_e <= 0 or self.t_k % self.n_e:
            raise GemmConfigError(
                f"element layer n_e={self.n_e} must divide t_k={self.t_k}")

    # -- working-set accounting (paper Eq. 5 generalized to rectangles) ---
    def tile_bytes(self) -> int:
        """K(S,T): bytes of the A+B tile pair a block keeps resident."""
        s = _SIZEOF[self.dtype]
        return (self.t_m * self.t_k + self.t_k * self.t_n) * s

    def vmem_bytes(self) -> int:
        """Total VMEM per grid cell: A, B, C-in, C-out, accumulator."""
        s = _SIZEOF[self.dtype]
        acc = self.t_m * self.t_n * s  # accumulator is same-width here
        return self.tile_bytes() + 3 * self.t_m * self.t_n * s + acc - \
            self.t_m * self.t_n * s  # C-in + C-out + acc = 3 tiles

    def fits_vmem(self) -> bool:
        return self.vmem_bytes() <= VMEM_BYTES

    def grid(self) -> tuple[int, int, int]:
        """Paper Eq. 3 — blocks in the grid per dimension."""
        return (self.m // self.t_m, self.n // self.t_n, self.k // self.t_k)

    def flops(self) -> int:
        """Paper Eq. 2 generalized: 2*M*N*K multiply-adds + scale/add."""
        return 2 * self.m * self.n * self.k + 3 * self.m * self.n


def square(n: int, t: int, *, n_e: int = 1, dtype: str = "f32",
           alpha: float = 1.0, beta: float = 1.0) -> GemmSpec:
    """The paper's configuration: quadratic matrices, square tiles."""
    return GemmSpec(m=n, n=n, k=n, t_m=t, t_n=t, t_k=t, n_e=n_e,
                    dtype=dtype, alpha=alpha, beta=beta)


def make_gemm(spec: GemmSpec, *, interpret: bool = True):
    """Build the pallas_call for a tuning point.

    Returns ``f(a, b, c) -> alpha * a @ b + beta * c`` with shapes
    ``a:(m,k) b:(k,n) c:(m,n)``.
    """
    spec.validate()
    dtype = _DTYPES[spec.dtype]
    acc_dtype = dtype  # accumulate at operand width (paper does the same)
    g_m, g_n, g_k = spec.grid()

    kern = functools.partial(_gemm_kernel, n_k_grid=g_k, n_e=spec.n_e,
                             alpha=spec.alpha, beta=spec.beta)
    return pl.pallas_call(
        kern,
        grid=(g_m, g_n, g_k),
        in_specs=[
            pl.BlockSpec((spec.t_m, spec.t_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((spec.t_k, spec.t_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((spec.t_m, spec.t_n), lambda m, n, k: (m, n)),
        ],
        out_specs=pl.BlockSpec((spec.t_m, spec.t_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((spec.m, spec.n), dtype),
        scratch_shapes=[pltpu.VMEM((spec.t_m, spec.t_n), acc_dtype)],
        interpret=interpret,
    )


def example_args(spec: GemmSpec):
    """ShapeDtypeStructs for AOT lowering."""
    dtype = _DTYPES[spec.dtype]
    return (jax.ShapeDtypeStruct((spec.m, spec.k), dtype),
            jax.ShapeDtypeStruct((spec.k, spec.n), dtype),
            jax.ShapeDtypeStruct((spec.m, spec.n), dtype))
