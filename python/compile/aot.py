"""AOT compile path: lower every artifact variant to HLO *text* + manifest.

Run as ``python -m compile.aot --out-dir ../artifacts`` from ``python/``
(that is what ``make artifacts`` does). For every variant this module:

1. builds the L2 graph (which embeds the L1 Pallas kernel, interpret=True),
2. lowers it via jax.jit(...).lower(...) to stablehlo and converts to an
   XlaComputation to obtain **HLO text** — the only interchange format the
   image's xla_extension 0.5.1 accepts (jax>=0.5 serialized protos carry
   64-bit instruction ids it rejects; the text parser reassigns ids),
3. executes it once on deterministic splitmix64 inputs (shared bit-exactly
   with rust — see prng.py) and records an output digest,
4. appends the variant to ``manifest.json`` so the rust runtime can load,
   execute and *verify* every artifact without python.

The variant set covers: the pytest/integration correctness grid, the
native tile-size tuning sweep (paper Fig. 3 transplanted to the host CPU),
the element-layer ablation, the scaling series (Fig. 6/7 analogue), the
XLA-dot baseline, and the MLP application graph.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)  # f64 artifacts need x64

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model, prng  # noqa: E402
from .kernels.gemm_tiled import GemmSpec, square  # noqa: E402

MANIFEST_VERSION = 2
_DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Variant registry
# --------------------------------------------------------------------------


def gemm_id(spec: GemmSpec, kind: str = "gemm") -> str:
    sq = spec.m == spec.n == spec.k and spec.t_m == spec.t_n == spec.t_k
    if kind == "dot":
        return f"dot_n{spec.n}_{spec.dtype}"
    if sq:
        base = f"gemm_n{spec.n}_t{spec.t_n}_e{spec.n_e}_{spec.dtype}"
    else:
        base = (f"gemm_m{spec.m}n{spec.n}k{spec.k}"
                f"_t{spec.t_m}x{spec.t_n}x{spec.t_k}_e{spec.n_e}_{spec.dtype}")
    if spec.alpha != 1.0 or spec.beta != 1.0:
        base += f"_a{spec.alpha:g}_b{spec.beta:g}"
    return base


def variants() -> list[dict]:
    """The full artifact set. Keep lowering time for `make artifacts`
    around a couple of minutes; correctness breadth lives in pytest which
    builds kernels on the fly."""
    out: list[dict] = []

    def add_gemm(spec: GemmSpec, role: str, kind: str = "gemm"):
        out.append({"kind": kind, "role": role, "spec": spec})

    # native tile-size tuning sweep (Fig. 3 analogue on host CPU);
    # registered FIRST so the sweep role owns its ids (dedupe below)
    for t in (4, 8, 16, 32, 64, 128):
        add_gemm(square(256, t, dtype="f32"), role="tile_sweep")
    for t in (8, 16, 32, 64):
        add_gemm(square(256, t, dtype="f64"), role="tile_sweep")

    # correctness grid (rust integration tests verify digests of these)
    for n, t in [(128, 8), (128, 16), (128, 32), (256, 16), (256, 32)]:
        for dtype in ("f32", "f64"):
            add_gemm(square(n, t, dtype=dtype), role="correctness")
    # alpha/beta generality
    add_gemm(square(128, 16, dtype="f32", alpha=1.5, beta=0.5),
             role="correctness")
    add_gemm(square(128, 16, dtype="f64", alpha=-0.25, beta=2.0),
             role="correctness")
    # rectangular + non-square tiles
    add_gemm(GemmSpec(m=128, n=64, k=256, t_m=32, t_n=16, t_k=64,
                      dtype="f32"), role="correctness")

    # element-layer ablation (paper Fig. 1 element layer)
    for e in (2, 4, 8):
        add_gemm(square(256, 32, n_e=e, dtype="f32"), role="element_sweep")

    # scaling series (Fig. 6/7 analogue)
    for n in (64, 128, 192, 256, 384, 512):
        add_gemm(square(n, 32, dtype="f32") if n % 32 == 0 else
                 square(n, 16, dtype="f32"), role="scaling")

    # baseline: XLA-native dot ("vendor BLAS")
    for n in (64, 128, 256, 384, 512):
        add_gemm(square(n, n, dtype="f32"), role="baseline", kind="dot")
    for n in (128, 256):
        add_gemm(square(n, n, dtype="f64"), role="baseline", kind="dot")

    # application model
    out.append({"kind": "mlp", "role": "application",
                "spec": model.MlpSpec()})

    # dedupe by id, keep first role
    seen, uniq = set(), []
    for v in out:
        vid = (gemm_id(v["spec"], v["kind"]) if v["kind"] != "mlp"
               else f"mlp_b{v['spec'].batch}_{v['spec'].dtype}")
        if vid in seen:
            continue
        seen.add(vid)
        v["id"] = vid
        uniq.append(v)
    return uniq


# --------------------------------------------------------------------------
# Digest: deterministic inputs -> output statistics the rust side re-checks
# --------------------------------------------------------------------------


def gemm_inputs(vid: str, spec: GemmSpec) -> list[np.ndarray]:
    return [prng.matrix(prng.seed_for(vid, 0), spec.m, spec.k, spec.dtype),
            prng.matrix(prng.seed_for(vid, 1), spec.k, spec.n, spec.dtype),
            prng.matrix(prng.seed_for(vid, 2), spec.m, spec.n, spec.dtype)]


def mlp_inputs(vid: str, spec: model.MlpSpec) -> list[np.ndarray]:
    shapes = [(spec.batch, spec.d_in), (spec.d_in, spec.d_hidden),
              (spec.d_hidden,), (spec.d_hidden, spec.d_out), (spec.d_out,)]
    return [prng.matrix(prng.seed_for(vid, i), s[0],
                        s[1] if len(s) > 1 else 1,
                        spec.dtype).reshape(s)
            for i, s in enumerate(shapes)]


def digest(out: np.ndarray, n_samples: int = 8) -> dict:
    flat = np.asarray(out, dtype=np.float64).ravel()
    idx = np.linspace(0, flat.size - 1, n_samples).astype(int)
    return {
        "shape": list(out.shape),
        "sum": float(flat.sum()),
        "abs_sum": float(np.abs(flat).sum()),
        "samples": [[int(i), float(flat[i])] for i in idx],
    }


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def build_fn(v: dict):
    kind, spec = v["kind"], v["spec"]
    if kind == "gemm":
        from .kernels import gemm_tiled
        return (model.gemm_model(spec),
                gemm_tiled.example_args(spec),
                gemm_inputs(v["id"], spec))
    if kind == "dot":
        from .kernels import gemm_tiled
        return (model.gemm_baseline(spec),
                gemm_tiled.example_args(spec),
                gemm_inputs(v["id"], spec))
    if kind == "mlp":
        return (model.mlp_forward(spec),
                model.mlp_example_args(spec),
                mlp_inputs(v["id"], spec))
    raise ValueError(f"unknown kind {kind}")


def spec_meta(v: dict) -> dict:
    spec = v["spec"]
    if v["kind"] == "mlp":
        return {"batch": spec.batch, "d_in": spec.d_in,
                "d_hidden": spec.d_hidden, "d_out": spec.d_out,
                "t": spec.t, "dtype": spec.dtype}
    return {"m": spec.m, "n": spec.n, "k": spec.k, "t_m": spec.t_m,
            "t_n": spec.t_n, "t_k": spec.t_k, "n_e": spec.n_e,
            "dtype": spec.dtype, "alpha": spec.alpha, "beta": spec.beta,
            "flops": spec.flops(), "tile_bytes": spec.tile_bytes(),
            "vmem_bytes": spec.vmem_bytes(), "grid": list(spec.grid())}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact id substrings to build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    t_total = time.time()
    for v in variants():
        vid = v["id"]
        if args.only and not any(s in vid for s in args.only.split(",")):
            continue
        t0 = time.time()
        fn, ex_args, inputs = build_fn(v)
        jitted = jax.jit(fn)
        lowered = jitted.lower(*ex_args)
        hlo = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{vid}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        out = np.asarray(jitted(*[jnp.asarray(x) for x in inputs]))
        entry = {
            "id": vid,
            "kind": v["kind"],
            "role": v["role"],
            "file": f"{vid}.hlo.txt",
            "spec": spec_meta(v),
            "inputs": [{"seed": prng.seed_for(vid, i), "shape": list(x.shape),
                        "dtype": v["spec"].dtype}
                       for i, x in enumerate(inputs)],
            "digest": digest(out),
            "hlo_bytes": len(hlo),
        }
        entries.append(entry)
        print(f"  [{time.time() - t0:6.2f}s] {vid}  ({len(hlo)} B hlo)")

    manifest = {
        "version": MANIFEST_VERSION,
        "jax_version": jax.__version__,
        "interchange": "hlo-text",
        "return_tuple": True,
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts in {time.time() - t_total:.1f}s "
          f"-> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
