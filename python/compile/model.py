"""Layer 2 — JAX compute graphs built on the Layer-1 kernel.

Everything the rust runtime executes is lowered from here (via aot.py):

* ``gemm_model``     — the paper's workload, C = alpha*A*B + beta*C through
                       the single-source Pallas kernel.
* ``gemm_baseline``  — the same contraction through XLA's native dot; the
                       "highly optimized vendor DGEMM" baseline of §2.1.
* ``mlp_forward``    — a two-layer MLP whose matmuls run through the Pallas
                       kernel: proves the kernel composes inside a larger
                       graph (an application, not just a microbenchmark).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import gemm_tiled
from .kernels.gemm_tiled import GemmSpec

_DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


def gemm_model(spec: GemmSpec, *, interpret: bool = True):
    """The tuned workload: one pallas_call, nothing else in the graph."""
    return gemm_tiled.make_gemm(spec, interpret=interpret)


def gemm_baseline(spec: GemmSpec):
    """XLA-native dot with identical semantics (vendor-BLAS stand-in)."""

    def f(a, b, c):
        return (spec.alpha * jnp.dot(a, b, preferred_element_type=a.dtype)
                + spec.beta * c)

    return f


# --------------------------------------------------------------------------
# Application model: 2-layer tanh MLP over the Pallas GEMM.
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MlpSpec:
    """Shapes for the MLP application artifact. All dims divisible by t."""

    batch: int = 64
    d_in: int = 256
    d_hidden: int = 128
    d_out: int = 64
    t: int = 32
    dtype: str = "f32"

    def gemm_specs(self) -> tuple[GemmSpec, GemmSpec]:
        g1 = GemmSpec(m=self.batch, n=self.d_hidden, k=self.d_in,
                      t_m=self.t, t_n=self.t, t_k=self.t,
                      dtype=self.dtype, alpha=1.0, beta=1.0)
        g2 = GemmSpec(m=self.batch, n=self.d_out, k=self.d_hidden,
                      t_m=self.t, t_n=self.t, t_k=self.t,
                      dtype=self.dtype, alpha=1.0, beta=1.0)
        return g1, g2


def mlp_forward(spec: MlpSpec, *, interpret: bool = True):
    """Returns f(x, w1, b1, w2, b2) -> logits, matmuls via the L1 kernel.

    The bias enters through the GEMM's beta*C term (broadcast to rows),
    so the kernel carries the full alpha*A@B + beta*C contract even inside
    the application graph.
    """
    g1, g2 = spec.gemm_specs()
    k1 = gemm_tiled.make_gemm(g1, interpret=interpret)
    k2 = gemm_tiled.make_gemm(g2, interpret=interpret)
    dtype = _DTYPES[spec.dtype]

    def f(x, w1, b1, w2, b2):
        c1 = jnp.broadcast_to(b1, (spec.batch, spec.d_hidden)).astype(dtype)
        h = jnp.tanh(k1(x, w1, c1))
        c2 = jnp.broadcast_to(b2, (spec.batch, spec.d_out)).astype(dtype)
        return k2(h, w2, c2)

    return f


def mlp_example_args(spec: MlpSpec):
    d = _DTYPES[spec.dtype]
    return (jax.ShapeDtypeStruct((spec.batch, spec.d_in), d),
            jax.ShapeDtypeStruct((spec.d_in, spec.d_hidden), d),
            jax.ShapeDtypeStruct((spec.d_hidden,), d),
            jax.ShapeDtypeStruct((spec.d_hidden, spec.d_out), d),
            jax.ShapeDtypeStruct((spec.d_out,), d))
