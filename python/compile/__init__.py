"""Build-time compile package for alpaka-rs.

Layer 2 (JAX model graphs) and Layer 1 (Pallas kernels) live here. This
package is used ONLY at build time by ``make artifacts``; the rust binary
consumes the lowered HLO text artifacts and never imports python.
"""
