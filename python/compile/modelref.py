"""Numpy-only deterministic model reference — the bit-exact twin of the
rust strict tier (``rust/src/model``, ``rust/src/util/numerics.rs``).

Platform ``tanh``/``exp`` are *not* correctly rounded — glibc, musl and
numpy's SIMD loops disagree in the last ulp — so cross-language bit
parity of the MLP's activation is impossible through libm. The
activation here is therefore built from correctly-rounded IEEE-754
basic operations only (``+ - * /``, ``floor``, ``copysign``, exact
power-of-two scaling), in **exactly** the operation order of the rust
implementation. Two programs performing the same sequence of correctly
rounded f64 ops produce the same bits on every conforming platform;
that is the entire parity argument, and ``mlp_parity.json`` is its
executable proof (written by ``tests/test_model_parity.py``, asserted
bit-for-bit by ``rust/tests/model_serve.rs``).

Keep the constants and evaluation order in sync with
``rust/src/util/numerics.rs`` / ``rust/src/gemm/verify.rs`` — any
reordering on either side breaks the KAT (which is the point).

No jax anywhere in this file: the reference must not depend on the
lowering stack it verifies.
"""

from __future__ import annotations

import numpy as np

from . import prng

# fdlibm's split of ln 2: n * LN2_HI is exact over the range-reduction
# domain, LN2_HI + LN2_LO carries ~107 bits. Decimal literals parse to
# the identical f64 bits as the rust constants (both sides round the
# decimal correctly).
LN2_HI = 6.93147180369123816490e-01
LN2_LO = 1.90821492927058770002e-10
INV_LN2 = 1.44269504088896338700e+00

# 1/k! for k = 0..13 — factorials up to 13! are exact in f64, so each
# quotient is correctly rounded, bit-identical to the rust array.
INV_FACT = [1.0, 1.0, 1.0 / 2.0, 1.0 / 6.0, 1.0 / 24.0, 1.0 / 120.0,
            1.0 / 720.0, 1.0 / 5040.0, 1.0 / 40320.0, 1.0 / 362880.0,
            1.0 / 3628800.0, 1.0 / 39916800.0, 1.0 / 479001600.0,
            1.0 / 6227020800.0]


def det_exp_neg(y):
    """Deterministic e^y for y in [-64, 0], elementwise over f64.

    Range reduction y = n*ln2 + r then a degree-13 Taylor polynomial in
    Horner form, scaled by an exact 2^n (ldexp) — op for op the rust
    ``det_exp_neg``.
    """
    y = np.asarray(y, dtype=np.float64)
    n = np.floor(y * INV_LN2 + 0.5)
    r = (y - n * LN2_HI) - n * LN2_LO
    p = np.full_like(y, INV_FACT[13])
    for k in range(12, -1, -1):
        p = p * r + INV_FACT[k]
    return np.ldexp(p, n.astype(np.int32))


def det_tanh(x):
    """Deterministic tanh via (1 - e^(-2|x|)) / (1 + e^(-2|x|)),
    sign restored by copysign, saturating to ±1 for |x| > 20 — the
    rust ``det_tanh``, elementwise over f64."""
    x = np.asarray(x, dtype=np.float64)
    ax = np.abs(x)
    # Saturated lanes are overridden below; clamp so det_exp_neg's
    # argument stays in its reduced range on those lanes.
    t = det_exp_neg(-2.0 * np.minimum(ax, 20.0))
    core = (1.0 - t) / (1.0 + t)
    out = np.where(ax > 20.0, 1.0, core)
    out = np.copysign(out, x)
    return np.where(np.isnan(x), x, out)


def det_tanh_f32(x):
    """f32 activation: evaluate in f64, round once — the rust
    ``det_tanh_f32`` (and numpy's one-``astype`` is the same single
    round-to-nearest-even)."""
    x32 = np.asarray(x, dtype=np.float32)
    return det_tanh(x32.astype(np.float64)).astype(np.float32)


def gemm_strict_f32(a, b, bias, alpha, beta, activate):
    """Strict-tier layer: out = act(alpha*(a@b) + beta*bias) with f32
    accumulation in ascending-k order.

    The k-loop performs, per element, one rounded f32 multiply then one
    rounded f32 add per k step — identical to the rust reference's
    ``orow[j] += aik * brow[j]`` — so the accumulated product is
    bit-identical, not merely close. The epilogue is the tuned store
    loop's expression order: ``alpha*acc + beta*bias`` (two rounded
    multiplies, one rounded add), then the deterministic tanh on
    activating layers.
    """
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    acc = np.zeros((m, n), dtype=np.float32)
    for kk in range(k):
        acc += a[:, kk:kk + 1] * b[kk:kk + 1, :]
    bias_row = np.asarray(bias, dtype=np.float32).reshape(1, n)
    pre = np.float32(alpha) * acc + np.float32(beta) * bias_row
    if activate:
        return det_tanh_f32(pre)
    return pre


def mlp_forward_strict(model_id, batch, d_in, d_hidden, d_out,
                       alpha=1.0, beta=1.0):
    """Run the 2-layer MLP strictly from its seeded inputs (the aot.py
    argument order x, w1, b1, w2, b2 → seed positions 0..4). Returns
    every post-activation layer output, f32 — the values the rust
    strict tier serves for the same manifest entry."""
    seeds = [prng.seed_for(model_id, k) for k in range(5)]
    x = prng.matrix(seeds[0], batch, d_in, "f32")
    w1 = prng.matrix(seeds[1], d_in, d_hidden, "f32")
    b1 = prng.matrix(seeds[2], d_hidden, 1, "f32").ravel()
    w2 = prng.matrix(seeds[3], d_hidden, d_out, "f32")
    b2 = prng.matrix(seeds[4], d_out, 1, "f32").ravel()
    h = gemm_strict_f32(x, w1, b1, alpha, beta, activate=True)
    out = gemm_strict_f32(h, w2, b2, alpha, beta, activate=False)
    return [h, out]
